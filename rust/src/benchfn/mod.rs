//! Synthetic benchmark functions (paper §3, Fig. 3 + ablation workloads).
//!
//! All functions are *minimization* problems exposing a [`BenchFunction`]
//! trait: a search space plus an objective over [`Config`]s. The modified
//! mixed discrete-continuous Branin follows Halstrup (2016), the benchmark
//! the paper's `Branin_Benchmark.ipynb` uses.

use crate::space::{Config, SearchSpace};

/// A synthetic optimization benchmark (minimization convention).
pub trait BenchFunction: Send + Sync {
    fn name(&self) -> &'static str;
    fn space(&self) -> SearchSpace;
    fn eval(&self, cfg: &Config) -> f64;
    /// Known global minimum value (for regret curves).
    fn optimum(&self) -> f64;
}

/// Classic continuous Branin on [-5, 10] x [0, 15]; min 0.397887.
pub struct Branin;

pub(crate) fn branin_raw(x1: f64, x2: f64) -> f64 {
    let a = 1.0;
    let b = 5.1 / (4.0 * std::f64::consts::PI.powi(2));
    let c = 5.0 / std::f64::consts::PI;
    let r = 6.0;
    let s = 10.0;
    let t = 1.0 / (8.0 * std::f64::consts::PI);
    a * (x2 - b * x1 * x1 + c * x1 - r).powi(2) + s * (1.0 - t) * x1.cos() + s
}

impl BenchFunction for Branin {
    fn name(&self) -> &'static str {
        "branin"
    }

    fn space(&self) -> SearchSpace {
        SearchSpace::builder()
            .uniform("x1", -5.0, 10.0)
            .uniform("x2", 0.0, 15.0)
            .build()
    }

    fn eval(&self, cfg: &Config) -> f64 {
        branin_raw(cfg.get_f64("x1").unwrap(), cfg.get_f64("x2").unwrap())
    }

    fn optimum(&self) -> f64 {
        0.397887
    }
}

/// Modified Branin with mixed discrete and continuous variables (Halstrup
/// 2016; the paper's Fig. 3 benchmark): x1 continuous on [-5, 10], x2
/// discretized to the integers {0..15}.
pub struct MixedBranin;

impl BenchFunction for MixedBranin {
    fn name(&self) -> &'static str {
        "mixed_branin"
    }

    fn space(&self) -> SearchSpace {
        SearchSpace::builder()
            .uniform("x1", -5.0, 10.0)
            .int("x2", 0, 15)
            .build()
    }

    fn eval(&self, cfg: &Config) -> f64 {
        let x1 = cfg.get_f64("x1").unwrap();
        let x2 = cfg.get_i64("x2").unwrap() as f64;
        branin_raw(x1, x2)
    }

    fn optimum(&self) -> f64 {
        // min over integer x2 (computed numerically): branin(-3.0792, 12)
        // = 0.43234.
        0.43234
    }
}

/// Harder extension used by the ablations (not a paper figure): the mixed
/// Branin plus a *categorical* branch with a per-branch offset — stresses
/// joint reasoning over continuous, integer and categorical types, and is
/// a known lock-in trap for TPE-style per-dimension density models.
pub struct CatBranin;

impl BenchFunction for CatBranin {
    fn name(&self) -> &'static str {
        "cat_branin"
    }

    fn space(&self) -> SearchSpace {
        SearchSpace::builder()
            .uniform("x1", -5.0, 10.0)
            .int("x2", 0, 15)
            .choice("branch", &["low", "mid", "high"])
            .build()
    }

    fn eval(&self, cfg: &Config) -> f64 {
        let x1 = cfg.get_f64("x1").unwrap();
        let x2 = cfg.get_i64("x2").unwrap() as f64;
        let offset = match cfg.get_str("branch").unwrap() {
            "low" => 0.0,
            "mid" => 5.0,
            _ => 15.0,
        };
        branin_raw(x1, x2) + offset
    }

    fn optimum(&self) -> f64 {
        // Global minimum sits on the 'low' branch at the MixedBranin optimum.
        0.43234
    }
}

/// Rosenbrock in d dims on [-2, 2]^d; min 0 at (1, ..., 1).
pub struct Rosenbrock(pub usize);

impl BenchFunction for Rosenbrock {
    fn name(&self) -> &'static str {
        "rosenbrock"
    }

    fn space(&self) -> SearchSpace {
        let mut b = SearchSpace::builder();
        for i in 0..self.0 {
            b = b.uniform(&format!("x{i}"), -2.0, 2.0);
        }
        b.build()
    }

    fn eval(&self, cfg: &Config) -> f64 {
        let x: Vec<f64> = (0..self.0).map(|i| cfg.get_f64(&format!("x{i}")).unwrap()).collect();
        (0..self.0 - 1)
            .map(|i| 100.0 * (x[i + 1] - x[i] * x[i]).powi(2) + (1.0 - x[i]).powi(2))
            .sum()
    }

    fn optimum(&self) -> f64 {
        0.0
    }
}

/// Ackley in d dims on [-5, 5]^d; min 0 at the origin.
pub struct Ackley(pub usize);

impl BenchFunction for Ackley {
    fn name(&self) -> &'static str {
        "ackley"
    }

    fn space(&self) -> SearchSpace {
        let mut b = SearchSpace::builder();
        for i in 0..self.0 {
            b = b.uniform(&format!("x{i}"), -5.0, 5.0);
        }
        b.build()
    }

    fn eval(&self, cfg: &Config) -> f64 {
        let d = self.0 as f64;
        let x: Vec<f64> = (0..self.0).map(|i| cfg.get_f64(&format!("x{i}")).unwrap()).collect();
        let s1: f64 = x.iter().map(|v| v * v).sum::<f64>() / d;
        let s2: f64 =
            x.iter().map(|v| (2.0 * std::f64::consts::PI * v).cos()).sum::<f64>() / d;
        -20.0 * (-0.2 * s1.sqrt()).exp() - s2.exp() + 20.0 + std::f64::consts::E
    }

    fn optimum(&self) -> f64 {
        0.0
    }
}

/// Hartmann-6 on [0, 1]^6; min -3.32237.
pub struct Hartmann6;

impl BenchFunction for Hartmann6 {
    fn name(&self) -> &'static str {
        "hartmann6"
    }

    fn space(&self) -> SearchSpace {
        let mut b = SearchSpace::builder();
        for i in 0..6 {
            b = b.uniform(&format!("x{i}"), 0.0, 1.0);
        }
        b.build()
    }

    fn eval(&self, cfg: &Config) -> f64 {
        const ALPHA: [f64; 4] = [1.0, 1.2, 3.0, 3.2];
        const A: [[f64; 6]; 4] = [
            [10.0, 3.0, 17.0, 3.5, 1.7, 8.0],
            [0.05, 10.0, 17.0, 0.1, 8.0, 14.0],
            [3.0, 3.5, 1.7, 10.0, 17.0, 8.0],
            [17.0, 8.0, 0.05, 10.0, 0.1, 14.0],
        ];
        const P: [[f64; 6]; 4] = [
            [0.1312, 0.1696, 0.5569, 0.0124, 0.8283, 0.5886],
            [0.2329, 0.4135, 0.8307, 0.3736, 0.1004, 0.9991],
            [0.2348, 0.1451, 0.3522, 0.2883, 0.3047, 0.6650],
            [0.4047, 0.8828, 0.8732, 0.5743, 0.1091, 0.0381],
        ];
        let x: Vec<f64> = (0..6).map(|i| cfg.get_f64(&format!("x{i}")).unwrap()).collect();
        -(0..4)
            .map(|i| {
                let inner: f64 = (0..6).map(|j| A[i][j] * (x[j] - P[i][j]).powi(2)).sum();
                ALPHA[i] * (-inner).exp()
            })
            .sum::<f64>()
    }

    fn optimum(&self) -> f64 {
        -3.32237
    }
}

/// All benchmark functions by name (used by the CLI and ablation benches).
pub fn by_name(name: &str) -> Option<Box<dyn BenchFunction>> {
    match name {
        "branin" => Some(Box::new(Branin)),
        "mixed_branin" => Some(Box::new(MixedBranin)),
        "cat_branin" => Some(Box::new(CatBranin)),
        "rosenbrock" => Some(Box::new(Rosenbrock(4))),
        "ackley" => Some(Box::new(Ackley(4))),
        "hartmann6" => Some(Box::new(Hartmann6)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::ParamValue;
    use crate::util::rng::Pcg64;

    fn cfg2(x1: f64, x2: f64) -> Config {
        Config::new(vec![("x1".into(), ParamValue::F64(x1)), ("x2".into(), ParamValue::F64(x2))])
    }

    #[test]
    fn branin_known_minima() {
        for (x1, x2) in [
            (-std::f64::consts::PI, 12.275),
            (std::f64::consts::PI, 2.275),
            (9.42478, 2.475),
        ] {
            let v = Branin.eval(&cfg2(x1, x2));
            assert!((v - 0.397887).abs() < 1e-4, "branin({x1},{x2}) = {v}");
        }
    }

    #[test]
    fn cat_branin_branch_offsets() {
        let base = Config::new(vec![
            ("x1".into(), ParamValue::F64(3.0)),
            ("x2".into(), ParamValue::Int(2)),
            ("branch".into(), ParamValue::Str("low".into())),
        ]);
        let mut mid = base.clone();
        mid.set("branch", ParamValue::Str("mid".into()));
        let mut high = base.clone();
        high.set("branch", ParamValue::Str("high".into()));
        let (a, b, c) = (CatBranin.eval(&base), CatBranin.eval(&mid), CatBranin.eval(&high));
        assert!((b - a - 5.0).abs() < 1e-12);
        assert!((c - a - 15.0).abs() < 1e-12);
        // mixed == cat on the low branch
        let mixed = Config::new(vec![
            ("x1".into(), ParamValue::F64(3.0)),
            ("x2".into(), ParamValue::Int(2)),
        ]);
        assert_eq!(MixedBranin.eval(&mixed), a);
    }

    #[test]
    fn mixed_branin_optimum_reachable() {
        let f = MixedBranin;
        let s = f.space();
        let mut rng = Pcg64::new(1);
        let best = (0..20_000)
            .map(|_| f.eval(&s.sample(&mut rng)))
            .fold(f64::INFINITY, f64::min);
        assert!(best < f.optimum() + 0.5, "best random = {best}");
        assert!(best >= f.optimum() - 1e-6, "optimum documented too high: {best}");
    }

    #[test]
    fn rosenbrock_zero_at_ones() {
        let f = Rosenbrock(4);
        let cfg =
            Config::new((0..4).map(|i| (format!("x{i}"), ParamValue::F64(1.0))).collect());
        assert!(f.eval(&cfg).abs() < 1e-12);
    }

    #[test]
    fn ackley_zero_at_origin() {
        let f = Ackley(3);
        let cfg =
            Config::new((0..3).map(|i| (format!("x{i}"), ParamValue::F64(0.0))).collect());
        assert!(f.eval(&cfg).abs() < 1e-9);
    }

    #[test]
    fn hartmann6_known_minimum() {
        let xstar = [0.20169, 0.150011, 0.476874, 0.275332, 0.311652, 0.6573];
        let cfg = Config::new(
            xstar.iter().enumerate().map(|(i, &v)| (format!("x{i}"), ParamValue::F64(v))).collect(),
        );
        let v = Hartmann6.eval(&cfg);
        assert!((v - (-3.32237)).abs() < 1e-3, "{v}");
    }

    #[test]
    fn registry_covers_all() {
        for name in ["branin", "mixed_branin", "cat_branin", "rosenbrock", "ackley", "hartmann6"] {
            let f = by_name(name).unwrap();
            let mut rng = Pcg64::new(0);
            let v = f.eval(&f.space().sample(&mut rng));
            assert!(v.is_finite());
            assert!(v >= f.optimum() - 1e-6);
        }
        assert!(by_name("nope").is_none());
    }
}
