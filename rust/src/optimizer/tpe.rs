//! Tree-structured Parzen Estimator — the in-repo Hyperopt comparator
//! (Bergstra et al. 2011, as implemented by hyperopt's `tpe.suggest`).
//!
//! Observations are split at the γ-quantile into "good" and "bad" sets;
//! each hyperparameter gets a pair of 1-D Parzen estimators (adaptive-width
//! Gaussian mixtures for numeric dims — log-space for loguniform —,
//! smoothed categorical histograms for choices). Candidates are sampled
//! from l(x) (the good-set estimator) and ranked by l(x)/g(x) (equivalent
//! to the EI argmax under the TPE derivation). Parallel batches take the
//! top-k distinct candidates — what hyperopt does under its async
//! constant-liar parallelism.

use super::{BatchOptimizer, History};
use crate::space::{Config, Domain, ParamValue, SearchSpace};
use crate::util::rng::Pcg64;
use crate::util::stats::nan_as_worst;
use anyhow::Result;

/// Fraction of observations considered "good".
const GAMMA: f64 = 0.25;
/// Candidates drawn from l(x) per proposal round (hyperopt default 24).
const N_EI_CANDIDATES: usize = 24;
/// Random evaluations before the Parzen estimators engage (hyperopt's
/// `n_startup_jobs` default). Prevents early lock-in on a lucky region.
const N_STARTUP: usize = 20;

pub struct TpeOptimizer {
    space: SearchSpace,
}

impl TpeOptimizer {
    pub fn new(space: SearchSpace) -> Self {
        Self { space }
    }
}

/// 1-D Parzen estimator for one hyperparameter.
enum Parzen {
    /// Gaussian mixture over (possibly log-transformed) numeric values,
    /// with a wide prior component covering the whole range.
    Numeric {
        log: bool,
        lo: f64,
        hi: f64,
        round: bool,
        q: Option<f64>,
        centers: Vec<f64>,
        widths: Vec<f64>,
    },
    /// Smoothed categorical histogram.
    Categorical { values: Vec<ParamValue>, weights: Vec<f64> },
}

fn norm_pdf(x: f64, mu: f64, sigma: f64) -> f64 {
    let z = (x - mu) / sigma;
    (-0.5 * z * z).exp() / (sigma * (2.0 * std::f64::consts::PI).sqrt())
}

impl Parzen {
    /// Build the estimator for `domain` from the observed `values`.
    fn build(domain: &Domain, values: &[&ParamValue]) -> Parzen {
        match domain {
            Domain::Choice(choices) => {
                let k = choices.len();
                let mut counts = vec![1.0; k]; // add-one smoothing (prior)
                for v in values {
                    if let Some(i) = choices.iter().position(|c| &c == v) {
                        counts[i] += 1.0;
                    }
                }
                let total: f64 = counts.iter().sum();
                Parzen::Categorical {
                    values: choices.clone(),
                    weights: counts.into_iter().map(|c| c / total).collect(),
                }
            }
            _ => {
                let (lo, hi, log, round, q) = match domain {
                    Domain::Uniform { lo, hi } => (*lo, *hi, false, false, None),
                    Domain::LogUniform { lo, hi } => (lo.ln(), hi.ln(), true, false, None),
                    Domain::QUniform { lo, hi, q } => (*lo, *hi, false, false, Some(*q)),
                    Domain::Normal { mean, std } => {
                        (mean - 3.0 * std, mean + 3.0 * std, false, false, None)
                    }
                    Domain::Range { lo, hi } => (*lo as f64, *hi as f64, false, true, None),
                    Domain::Custom(d) => {
                        let (l, h) = d.bounds();
                        (l, h, false, false, None)
                    }
                    Domain::Choice(_) => unreachable!(),
                };
                let range = (hi - lo).max(1e-12);
                let mut centers: Vec<f64> = values
                    .iter()
                    .filter_map(|v| v.as_f64())
                    .map(|v| if log { v.max(1e-300).ln() } else { v })
                    .collect();
                centers.sort_by(|a, b| a.total_cmp(b));
                // Adaptive widths (hyperopt's adaptive_parzen_normal): max
                // distance to the sorted neighbours, bounds acting as
                // virtual neighbours for the extremes, clipped to
                // [range / min(100, n+1), range] — the generous floor keeps
                // the estimator exploratory enough to refine locally.
                let n = centers.len();
                let bw_min = (range / (n as f64 + 1.0).min(100.0)).max(1e-9);
                let widths: Vec<f64> = (0..n)
                    .map(|i| {
                        let prev = if i > 0 { centers[i - 1] } else { lo };
                        let next = if i + 1 < n { centers[i + 1] } else { hi };
                        (centers[i] - prev).max(next - centers[i]).clamp(bw_min, range)
                    })
                    .collect();
                // Prior component: wide Gaussian over the whole range.
                let mut c = Vec::with_capacity(n + 1);
                let mut w = Vec::with_capacity(n + 1);
                c.push((lo + hi) / 2.0);
                w.push(range);
                c.extend(centers);
                w.extend(widths);
                Parzen::Numeric { log, lo, hi, round, q, centers: c, widths: w }
            }
        }
    }

    /// Sample one value.
    fn sample(&self, rng: &mut Pcg64) -> ParamValue {
        match self {
            Parzen::Categorical { values, weights } => {
                values[rng.weighted_index(weights)].clone()
            }
            Parzen::Numeric { log, lo, hi, round, q, centers, widths } => {
                let i = rng.uniform_usize(0, centers.len());
                let mut v = rng.normal_scaled(centers[i], widths[i]).clamp(*lo, *hi);
                if *log {
                    v = v.exp();
                }
                if let Some(q) = q {
                    v = (v / q).round() * q;
                }
                if *round {
                    ParamValue::Int(v.round() as i64)
                } else {
                    ParamValue::F64(v)
                }
            }
        }
    }

    /// Mixture density of one value.
    fn pdf(&self, v: &ParamValue) -> f64 {
        match self {
            Parzen::Categorical { values, weights } => values
                .iter()
                .position(|c| c == v)
                .map(|i| weights[i])
                .unwrap_or(1e-12),
            Parzen::Numeric { log, centers, widths, .. } => {
                let Some(mut x) = v.as_f64() else { return 1e-12 };
                if *log {
                    x = x.max(1e-300).ln();
                }
                let n = centers.len() as f64;
                centers
                    .iter()
                    .zip(widths)
                    .map(|(&c, &w)| norm_pdf(x, c, w) / n)
                    .sum::<f64>()
                    .max(1e-300)
            }
        }
    }
}

impl BatchOptimizer for TpeOptimizer {
    fn propose(
        &mut self,
        history: &History,
        batch_size: usize,
        rng: &mut Pcg64,
    ) -> Result<Vec<Config>> {
        let n = history.len();
        if n < N_STARTUP {
            // Cold start goes through the one shared sampling path (the
            // columnar sampler; bit-identical to the legacy sample_n
            // stream) — the batch materializes anyway.
            return Ok(self.space.sample_columnar(rng, batch_size).into_configs());
        }
        // Split at the gamma quantile (maximization: good = highest values).
        let n_good = ((GAMMA * n as f64).ceil() as usize).clamp(2, 25);
        let mut order: Vec<usize> = (0..n).collect();
        // NaN values (hand-edited history dumps bypass the tuner's
        // is_finite guard) sort as the worst observations — into the "bad"
        // Parzen set — instead of panicking or (total_cmp's raw order)
        // landing above +inf in the "good" set.
        order.sort_by(|&a, &b| {
            nan_as_worst(history.values()[b]).total_cmp(&nan_as_worst(history.values()[a]))
        });
        let good: Vec<usize> = order[..n_good].to_vec();
        let bad: Vec<usize> = order[n_good..].to_vec();

        // Per-parameter l and g estimators.
        let mut dims: Vec<(String, Parzen, Parzen)> = Vec::with_capacity(self.space.len());
        for p in self.space.params() {
            let gv: Vec<&ParamValue> =
                good.iter().filter_map(|&i| history.configs()[i].get(&p.name)).collect();
            let bv: Vec<&ParamValue> =
                bad.iter().filter_map(|&i| history.configs()[i].get(&p.name)).collect();
            let l = Parzen::build(&p.domain, &gv);
            let g = Parzen::build(&p.domain, &bv);
            dims.push((p.name.clone(), l, g));
        }

        // Draw candidates from l — plus a 25% slice straight from the space
        // prior (hyperopt keeps a prior component with annealed weight; the
        // explicit prior slice serves the same purpose and prevents early
        // lock-in on a lucky categorical branch) — and score all by l/g.
        let n_cand = N_EI_CANDIDATES.max(batch_size * 8);
        let n_prior = (n_cand / 4).max(1);
        let mut scored: Vec<(f64, Config)> = Vec::with_capacity(n_cand + n_prior);
        let mut push_scored = |cfg: Config, dims: &[(String, Parzen, Parzen)]| {
            let mut score = 0.0;
            for (name, l, g) in dims {
                let v = cfg.get(name).expect("candidate has all params");
                score += l.pdf(v).ln() - g.pdf(v).ln();
            }
            scored.push((score, cfg));
        };
        for _ in 0..n_cand {
            let entries = dims
                .iter()
                .map(|(name, l, _)| (name.clone(), l.sample(rng)))
                .collect();
            push_scored(Config::new(entries), &dims);
        }
        // The prior slice is a straight space sample: drawn as one batch
        // through the shared columnar sampling path (same RNG stream as
        // the per-config sample loop it replaces; these configs all
        // materialize anyway for Parzen scoring).
        for cfg in self.space.sample_columnar(rng, n_prior).into_configs() {
            push_scored(cfg, &dims);
        }
        scored.sort_by(|a, b| nan_as_worst(b.0).total_cmp(&nan_as_worst(a.0)));

        let mut batch: Vec<Config> = Vec::with_capacity(batch_size);
        for (_, cfg) in scored {
            if batch.len() == batch_size {
                break;
            }
            if !batch.contains(&cfg) {
                batch.push(cfg);
            }
        }
        while batch.len() < batch_size {
            batch.push(self.space.sample(rng));
        }
        Ok(batch)
    }

    fn name(&self) -> &'static str {
        "tpe"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{svm_space, SearchSpace};

    fn quadratic_history(space: &SearchSpace, n: usize, seed: u64) -> History {
        let mut rng = Pcg64::new(seed);
        let mut h = History::new();
        for cfg in space.sample_n(&mut rng, n) {
            let c = cfg.get_f64("c").unwrap();
            h.push(cfg, -(c - 70.0) * (c - 70.0));
        }
        h
    }

    #[test]
    fn proposals_concentrate_near_good_region() {
        let space = svm_space();
        let mut opt = TpeOptimizer::new(space.clone());
        let mut rng = Pcg64::new(21);
        let h = quadratic_history(&space, 40, 2);
        // Average proposal distance to optimum should beat random's ~35.
        let mut dsum = 0.0;
        let mut count = 0;
        for _ in 0..10 {
            for cfg in opt.propose(&h, 2, &mut rng).unwrap() {
                dsum += (cfg.get_f64("c").unwrap() - 70.0).abs();
                count += 1;
            }
        }
        let avg = dsum / count as f64;
        assert!(avg < 25.0, "TPE proposals too spread: avg |c-70| = {avg}");
    }

    #[test]
    fn tpe_on_quadratic_beats_random_search() {
        let space = svm_space();
        let run = |use_tpe: bool, seed: u64| -> f64 {
            let mut opt_tpe = TpeOptimizer::new(space.clone());
            let mut opt_rng = super::super::random::RandomOptimizer::new(space.clone());
            let mut rng = Pcg64::new(seed);
            let mut h = History::new();
            for _ in 0..30 {
                let batch = if use_tpe {
                    opt_tpe.propose(&h, 1, &mut rng).unwrap()
                } else {
                    opt_rng.propose(&h, 1, &mut rng).unwrap()
                };
                for cfg in batch {
                    let c = cfg.get_f64("c").unwrap();
                    h.push(cfg, -(c - 70.0) * (c - 70.0));
                }
            }
            h.best().unwrap().1
        };
        // Compare MEDIANS over many seeds: TPE (like hyperopt) has rare
        // straggler seeds that lock onto the wrong region — the paper's own
        // Fig. 3 shows exactly this for Hyperopt serial. The typical run
        // must clearly beat random search.
        let seeds: Vec<u64> = (1..=15).collect();
        let tpe: Vec<f64> = seeds.iter().map(|&s| run(true, s)).collect();
        let rnd: Vec<f64> = seeds.iter().map(|&s| run(false, s)).collect();
        let tpe_med = crate::util::stats::median(&tpe);
        let rnd_med = crate::util::stats::median(&rnd);
        assert!(
            tpe_med > rnd_med,
            "tpe median {tpe_med} vs random median {rnd_med}"
        );
    }

    #[test]
    fn handles_categorical_and_int_dims() {
        let space = SearchSpace::builder()
            .choice("kind", &["a", "b", "c"])
            .range("depth", 1, 10)
            .loguniform("lr", 1e-4, 1.0)
            .build();
        let mut opt = TpeOptimizer::new(space.clone());
        let mut rng = Pcg64::new(5);
        let mut h = History::new();
        // 'b' with high depth is good.
        for cfg in space.sample_n(&mut rng, 40) {
            let bonus = if cfg.get_str("kind") == Some("b") { 1.0 } else { 0.0 };
            let v = bonus + cfg.get_i64("depth").unwrap() as f64 * 0.1;
            h.push(cfg, v);
        }
        let batch = opt.propose(&h, 10, &mut rng).unwrap();
        assert_eq!(batch.len(), 10);
        let b_count = batch.iter().filter(|c| c.get_str("kind") == Some("b")).count();
        assert!(b_count >= 5, "TPE should prefer 'b', got {b_count}/10");
        for cfg in &batch {
            let d = cfg.get_i64("depth").unwrap();
            assert!((1..=9).contains(&d), "depth {d} out of range");
            let lr = cfg.get_f64("lr").unwrap();
            assert!((1e-4..=1.0).contains(&lr), "lr {lr} out of bounds");
        }
    }

    #[test]
    fn cold_start_is_random() {
        let space = svm_space();
        let mut opt = TpeOptimizer::new(space.clone());
        let mut rng = Pcg64::new(6);
        let batch = opt.propose(&History::new(), 3, &mut rng).unwrap();
        assert_eq!(batch.len(), 3);
    }

    #[test]
    fn nan_history_value_does_not_panic() {
        // Regression: the good/bad quantile split sorted with
        // partial_cmp().unwrap() and panicked on NaN (reachable via
        // hand-edited history dumps that bypass the tuner's is_finite
        // guard). total_cmp sorts NaN deterministically instead.
        let space = svm_space();
        let mut opt = TpeOptimizer::new(space.clone());
        let mut rng = Pcg64::new(77);
        let mut h = quadratic_history(&space, 25, 3); // past N_STARTUP
        h.push(space.sample(&mut rng), f64::NAN);
        let batch = opt.propose(&h, 4, &mut rng).unwrap();
        assert_eq!(batch.len(), 4);
    }
}
