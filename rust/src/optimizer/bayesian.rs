//! Shared GP-UCB machinery for the two batch Bayesian algorithms:
//! history encoding, y-normalization, incremental surrogate fitting (with
//! optional lengthscale selection by marginal likelihood), adaptive beta,
//! and Monte-Carlo acquisition scoring.
//!
//! Fits are *incremental*: [`BayesianCore`] keeps a persistent
//! [`CholeskyState`] per kernel-hyperparameter key, so each scheduling
//! round only pays O(n²) per new observation instead of an O(n³) refit
//! (the tuner's surrogate step stays cheap relative to trial evaluation —
//! the property Tune and Sherpa both call out as essential for parallel
//! tuning to scale). A state is reused only while the history window grows
//! append-only; `truncate_to_recent` windowing or a lengthscale retune
//! transparently fall back to one from-scratch factorization.

use super::{GpOptions, History, SurrogateBackend, YTransform};
use crate::acq;
use crate::gp::{normalize_y, AcquireOut, CholeskyState, FitOut, GpParams, NativeGp, Surrogate};
use crate::linalg::Matrix;
use crate::runtime::PjrtSurrogate;
use crate::space::{Config, Encoder, SearchSpace};
use crate::util::rng::Pcg64;
use anyhow::Result;

/// The LML lengthscale grid (unit-cube lengthscales probed per fit when
/// `tune_lengthscale` is on). Shared by `fit_and_score` and `rehydrate` so
/// recovery warms exactly the cache entries the grid search will hit.
pub const LML_LENGTHSCALE_GRID: [f64; 5] = [0.1, 0.2, 0.3, 0.5, 0.8];

/// Upper bound on cached Cholesky states: the LML grid search probes the
/// grid's 5 fixed lengthscales; +1 covers the fixed-default parameters.
const CHOL_CACHE_MAX: usize = LML_LENGTHSCALE_GRID.len() + 1;

/// One fit-and-score round over the history: everything a batch-selection
/// strategy needs.
pub struct Scored {
    /// Encoded observation matrix (n x d).
    pub x_obs: Matrix,
    /// Candidate configurations (the MC sample).
    pub candidates: Vec<Config>,
    /// Encoded candidates (m x d).
    pub xc: Matrix,
    pub acq: AcquireOut,
    pub params: GpParams,
}

pub struct BayesianCore {
    pub space: SearchSpace,
    pub encoder: Encoder,
    pub opts: GpOptions,
    surrogate: Box<dyn Surrogate>,
    /// Persistent Cholesky states, one per kernel-hyperparameter key seen
    /// recently; each grows by rank-1 appends across rounds and is dropped
    /// when its prefix breaks (windowing) or the cache overflows.
    chol_cache: Vec<CholeskyState>,
    /// Iterations seen (drives the adaptive beta schedule).
    pub rounds: usize,
}

impl BayesianCore {
    pub fn new(space: SearchSpace, opts: GpOptions) -> Result<Self> {
        let surrogate: Box<dyn Surrogate> = match opts.backend {
            SurrogateBackend::Native => Box::new(NativeGp),
            SurrogateBackend::Pjrt => Box::new(PjrtSurrogate::from_default_artifacts()?),
        };
        let encoder = Encoder::new(&space);
        Ok(Self { space, encoder, opts, surrogate, chol_cache: Vec::new(), rounds: 0 })
    }

    /// Max observations the surrogate can hold, answered by the backend
    /// itself ([`Surrogate::max_obs`]) — the PJRT backend reads its loaded
    /// artifact manifest, so this can never drift from the actual artifact
    /// capacity the way a hardcoded mirror could.
    pub fn max_obs(&self) -> usize {
        self.surrogate.max_obs()
    }

    /// Encode history into a padded-free (n x d) matrix.
    fn encode_history(&self, history: &History) -> Matrix {
        let d = self.encoder.dims();
        let flat = self.encoder.encode_batch(history.configs());
        Matrix::from_vec(history.len(), d, flat)
    }

    /// Fit through the Cholesky cache: pop the state matching `params`,
    /// extend it (or rebuild on a stale prefix), and store it back.
    fn fit_cached(&mut self, x: &Matrix, y: &[f64], params: &GpParams) -> Result<FitOut> {
        let state = self
            .chol_cache
            .iter()
            .position(|s| s.matches_params(params))
            .map(|i| self.chol_cache.swap_remove(i));
        let (fit, state) = self.surrogate.fit_incremental(x, y, params, state)?;
        if self.chol_cache.len() >= CHOL_CACHE_MAX {
            self.chol_cache.remove(0); // oldest key (grid keys re-insert every round)
        }
        self.chol_cache.push(state);
        Ok(fit)
    }

    /// Fit the surrogate and score an MC candidate set.
    ///
    /// `batch_size` feeds the adaptive beta (paper: exploration depends on
    /// batch size); `rng` drives candidate sampling and (if enabled) the
    /// lengthscale grid.
    pub fn fit_and_score(
        &mut self,
        history: &History,
        batch_size: usize,
        rng: &mut Pcg64,
    ) -> Result<Scored> {
        let x_obs = self.encode_history(history);
        let yn = match self.opts.y_transform {
            YTransform::Normalize => normalize_y(history.values()).0,
            YTransform::RankGauss => acq::rank_gauss(history.values()),
        };
        let d = self.encoder.dims();

        let beta = self.opts.fixed_beta.unwrap_or_else(|| {
            acq::adaptive_beta(self.rounds, self.space.cardinality_estimate(), batch_size)
        });
        self.rounds += 1;

        // Lengthscale: fixed default or LML grid search (paper: Mango
        // internally selects GP hyperparameters). Each grid point keeps its
        // own cached Cholesky state, so the whole grid stays incremental.
        let mut params = GpParams::new(d).with_beta(beta);
        params.noise = self.opts.noise;
        let fit = if self.opts.tune_lengthscale {
            let mut best: Option<(f64, GpParams, FitOut)> = None;
            for ls in LML_LENGTHSCALE_GRID {
                let mut p = GpParams::new(d).with_beta(beta).with_lengthscale(ls);
                p.noise = self.opts.noise;
                let f = self.fit_cached(&x_obs, &yn, &p)?;
                let lml = f.log_marginal_likelihood(&yn);
                if best.as_ref().map_or(true, |(b, _, _)| lml > *b) {
                    best = Some((lml, p, f));
                }
            }
            let (_, p, f) = best.unwrap();
            params = p;
            f
        } else {
            self.fit_cached(&x_obs, &yn, &params)?
        };

        let candidates = acq::mc_candidates(&self.space, self.opts.mc_samples, rng);
        let flat = self.encoder.encode_batch(&candidates);
        let xc = Matrix::from_vec(candidates.len(), d, flat);
        let acq_out = self.surrogate.acquire(&x_obs, &fit, &xc, &params)?;
        Ok(Scored { x_obs, candidates, xc, acq: acq_out, params })
    }

    pub fn backend_name(&self) -> &'static str {
        self.surrogate.name()
    }

    /// The cached [`CholeskyState`] matching `params`' kernel key, if any —
    /// introspection for the recovery tests (resume-rebuilt factor must be
    /// bit-identical to the uninterrupted run's).
    pub fn cached_state(&self, params: &GpParams) -> Option<&CholeskyState> {
        self.chol_cache.iter().find(|s| s.matches_params(params))
    }

    /// Restore state after a journal replay: set the adaptive-beta clock to
    /// the journaled `rounds` and warm the incremental Cholesky cache over
    /// the replayed history window, so the first post-resume fit pays the
    /// O(kn²) append path instead of an O(n³) from-scratch refactorization
    /// per kernel key. The warm-up itself is one factorization pass (O(n²)
    /// per replayed row — the same per-observation cost the uninterrupted
    /// run paid), and by the append/scratch equivalence property the
    /// resulting factor is bit-identical to the state the crashed process
    /// held over the same rows. With lengthscale tuning enabled every grid
    /// point is warmed, mirroring `fit_and_score`'s per-grid-point caches.
    pub fn rehydrate(&mut self, history: &History, rounds: usize) -> Result<()> {
        self.rounds = rounds;
        if history.is_empty() {
            return Ok(());
        }
        let x_obs = self.encode_history(history);
        let yn = match self.opts.y_transform {
            YTransform::Normalize => normalize_y(history.values()).0,
            YTransform::RankGauss => acq::rank_gauss(history.values()),
        };
        let d = self.encoder.dims();
        if self.opts.tune_lengthscale {
            for ls in LML_LENGTHSCALE_GRID {
                let mut p = GpParams::new(d).with_lengthscale(ls);
                p.noise = self.opts.noise;
                self.fit_cached(&x_obs, &yn, &p)?;
            }
        } else {
            let mut p = GpParams::new(d);
            p.noise = self.opts.noise;
            self.fit_cached(&x_obs, &yn, &p)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::svm_space;

    fn history_from(space: &SearchSpace, n: usize, seed: u64) -> History {
        let mut rng = Pcg64::new(seed);
        let mut h = History::new();
        for cfg in space.sample_n(&mut rng, n) {
            let v = -(cfg.get_f64("c").unwrap() - 50.0).abs();
            h.push(cfg, v);
        }
        h
    }

    #[test]
    fn fit_and_score_shapes() {
        let space = svm_space();
        let mut core = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        let h = history_from(&space, 12, 3);
        let mut rng = Pcg64::new(4);
        let s = core.fit_and_score(&h, 1, &mut rng).unwrap();
        assert_eq!(s.x_obs.rows(), 12);
        assert_eq!(s.candidates.len(), s.xc.rows());
        assert_eq!(s.acq.ucb.len(), s.candidates.len());
        assert_eq!(s.acq.w.rows(), 12);
    }

    #[test]
    fn rounds_advance_beta() {
        let space = svm_space();
        let mut core = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        let h = history_from(&space, 8, 5);
        let mut rng = Pcg64::new(6);
        let s1 = core.fit_and_score(&h, 1, &mut rng).unwrap();
        let s2 = core.fit_and_score(&h, 1, &mut rng).unwrap();
        assert!(s2.params.beta >= s1.params.beta);
        assert_eq!(core.rounds, 2);
    }

    #[test]
    fn fixed_beta_respected() {
        let space = svm_space();
        let opts = GpOptions { fixed_beta: Some(1.7), ..Default::default() };
        let mut core = BayesianCore::new(space.clone(), opts).unwrap();
        let h = history_from(&space, 8, 5);
        let mut rng = Pcg64::new(6);
        let s = core.fit_and_score(&h, 4, &mut rng).unwrap();
        assert_eq!(s.params.beta, 1.7);
    }

    #[test]
    fn lengthscale_tuning_runs() {
        let space = svm_space();
        let opts = GpOptions { tune_lengthscale: true, ..Default::default() };
        let mut core = BayesianCore::new(space.clone(), opts).unwrap();
        let h = history_from(&space, 15, 8);
        let mut rng = Pcg64::new(9);
        let s = core.fit_and_score(&h, 1, &mut rng).unwrap();
        let ls = 1.0 / s.params.inv_lengthscale[0];
        assert!(LML_LENGTHSCALE_GRID.iter().any(|&v| (ls - v).abs() < 1e-9));
    }

    #[test]
    fn max_obs_answers_from_the_backend() {
        let space = svm_space();
        let native = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        assert_eq!(native.max_obs(), usize::MAX, "native GP is unbounded");
        let opts = GpOptions { backend: SurrogateBackend::Pjrt, ..Default::default() };
        let pjrt = BayesianCore::new(space, opts).unwrap();
        // Must equal whatever the surrogate reports (manifest capacity, or
        // the fallback default when no artifacts are on disk) — not a
        // hardcoded optimizer-side constant.
        assert!(pjrt.max_obs() < usize::MAX, "pjrt artifacts are bounded");
        assert!(pjrt.max_obs() >= 128);
    }

    /// The Cholesky cache must be a pure optimization: a core that reuses
    /// its state across growing-history rounds produces *exactly* the same
    /// scores as a fresh core fitting from scratch (the append path is
    /// bit-identical arithmetic).
    #[test]
    fn chol_cache_matches_fresh_fits_exactly() {
        let space = svm_space();
        let opts = GpOptions { fixed_beta: Some(2.0), ..Default::default() };
        let h1 = history_from(&space, 10, 21);
        let mut h2 = h1.clone();
        for cfg in space.sample_n(&mut Pcg64::new(22), 3) {
            let v = -(cfg.get_f64("c").unwrap() - 50.0).abs();
            h2.push(cfg, v);
        }

        let mut warm = BayesianCore::new(space.clone(), opts.clone()).unwrap();
        warm.fit_and_score(&h1, 1, &mut Pcg64::new(30)).unwrap(); // primes the cache
        let s_warm = warm.fit_and_score(&h2, 1, &mut Pcg64::new(31)).unwrap();

        let mut fresh = BayesianCore::new(space, opts).unwrap();
        let s_fresh = fresh.fit_and_score(&h2, 1, &mut Pcg64::new(31)).unwrap();

        assert_eq!(s_warm.acq.mean, s_fresh.acq.mean);
        assert_eq!(s_warm.acq.var, s_fresh.acq.var);
        assert_eq!(s_warm.acq.ucb, s_fresh.acq.ucb);
    }

    /// Windowing (`truncate_to_recent` / `recent`) breaks the cached
    /// prefix; the refit must be transparent and exact.
    #[test]
    fn window_shrink_invalidates_cache_transparently() {
        let space = svm_space();
        let opts = GpOptions { fixed_beta: Some(2.0), ..Default::default() };
        let h = history_from(&space, 14, 23);
        let shrunk = h.recent(9); // drops the 5 oldest observations

        let mut warm = BayesianCore::new(space.clone(), opts.clone()).unwrap();
        warm.fit_and_score(&h, 1, &mut Pcg64::new(40)).unwrap();
        let s_warm = warm.fit_and_score(&shrunk, 1, &mut Pcg64::new(41)).unwrap();

        let mut fresh = BayesianCore::new(space, opts).unwrap();
        let s_fresh = fresh.fit_and_score(&shrunk, 1, &mut Pcg64::new(41)).unwrap();

        assert_eq!(s_warm.acq.mean, s_fresh.acq.mean);
        assert_eq!(s_warm.acq.var, s_fresh.acq.var);
        assert_eq!(s_warm.acq.ucb, s_fresh.acq.ucb);
    }

    #[test]
    fn grid_search_keeps_one_state_per_lengthscale() {
        let space = svm_space();
        let opts =
            GpOptions { tune_lengthscale: true, fixed_beta: Some(2.0), ..Default::default() };
        let mut core = BayesianCore::new(space.clone(), opts).unwrap();
        let h = history_from(&space, 10, 25);
        core.fit_and_score(&h, 1, &mut Pcg64::new(50)).unwrap();
        assert_eq!(
            core.chol_cache.len(),
            LML_LENGTHSCALE_GRID.len(),
            "one cached state per grid point"
        );
        // A second round reuses all five without growing the cache.
        core.fit_and_score(&h, 1, &mut Pcg64::new(51)).unwrap();
        assert_eq!(core.chol_cache.len(), LML_LENGTHSCALE_GRID.len());
        assert!(core.chol_cache.iter().all(|s| s.rows() == 10));
    }
}
