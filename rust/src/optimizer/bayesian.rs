//! Shared GP-UCB machinery for the two batch Bayesian algorithms:
//! history encoding, y-normalization, incremental surrogate fitting (with
//! optional lengthscale selection by marginal likelihood), adaptive beta,
//! and Monte-Carlo acquisition scoring.
//!
//! Fits are *incremental*: [`BayesianCore`] keeps a persistent
//! [`CholeskyState`] per kernel-hyperparameter key, so each scheduling
//! round only pays O(n²) per new observation instead of an O(n³) refit
//! (the tuner's surrogate step stays cheap relative to trial evaluation —
//! the property Tune and Sherpa both call out as essential for parallel
//! tuning to scale). A state is reused only while the history window grows
//! append-only; `truncate_to_recent` windowing or a lengthscale retune
//! transparently fall back to one from-scratch factorization.

use super::{GpOptions, History, SurrogateBackend, YTransform};
use crate::acq;
use crate::gp::{
    self, kernel, normalize_y, AcquireOut, CholeskyState, FitOut, GpParams, NativeGp, Surrogate,
};
use crate::linalg::Matrix;
use crate::runtime::PjrtSurrogate;
use crate::space::{ColumnarSet, Config, Encoder, SearchSpace};
use crate::util::rng::Pcg64;
use anyhow::Result;

/// The LML lengthscale grid (unit-cube lengthscales probed per fit when
/// `tune_lengthscale` is on). Shared by `fit_and_score` and `rehydrate` so
/// recovery warms exactly the cache entries the grid search will hit.
pub const LML_LENGTHSCALE_GRID: [f64; 5] = [0.1, 0.2, 0.3, 0.5, 0.8];

/// Upper bound on cached Cholesky states: the LML grid search probes the
/// grid's 5 fixed lengthscales; +1 covers the fixed-default parameters.
const CHOL_CACHE_MAX: usize = LML_LENGTHSCALE_GRID.len() + 1;

/// One fit-and-score round over the history: everything a batch-selection
/// strategy needs.
///
/// The MC candidate set is **columnar** ([`ColumnarSet`]): typed SoA
/// columns instead of `m` materialized `Config`s — a batch-selection
/// strategy materializes only its ≤ batch-size winners via
/// [`ColumnarSet::config`].
pub struct Scored {
    /// Encoded observation matrix (n x d).
    pub x_obs: Matrix,
    /// The MC candidate set in columnar form (its encoded matrix has been
    /// moved out into [`Scored::xc`]).
    pub cands: ColumnarSet,
    /// Encoded candidates (m x d).
    pub xc: Matrix,
    pub acq: AcquireOut,
    pub params: GpParams,
}

/// Cached pairwise squared distances over the encoded observation rows —
/// the *unscaled* D² every isotropic kernel build derives its Gram from
/// (`exp(−0.5·il²·D²)`), so one matrix per round feeds the whole LML
/// lengthscale grid. Maintained with the same append-only prefix-reuse
/// discipline as the Cholesky cache: one new row per new observation, a
/// divergent tail (async constant-liar fits) truncates to the shared
/// prefix and regrows, and a window slide (prefix 0) rebuilds from
/// scratch. Entries are bit-stable across all three paths
/// ([`kernel::sq_dists`] == `dot`-derived rows, by the `matmul_transb`
/// contract), so feeding the cache into a fit is a pure precomputation.
struct DistCache {
    /// Encoded rows the distances cover.
    x: Matrix,
    /// Row squared norms (sequential-`dot` reduction, appendable).
    norms: Vec<f64>,
    /// Pairwise squared distances (n x n, symmetric).
    d2: Matrix,
}

/// Incrementally encoded history rows: re-encoding is deterministic, so a
/// shared leading-config prefix re-uses its encoded rows bitwise and only
/// the appended tail is encoded each round.
#[derive(Default)]
struct EncodeCache {
    configs: Vec<Config>,
    flat: Vec<f64>,
}

pub struct BayesianCore {
    pub space: SearchSpace,
    pub encoder: Encoder,
    pub opts: GpOptions,
    surrogate: Box<dyn Surrogate>,
    /// Persistent Cholesky states, one per kernel-hyperparameter key, in
    /// least-recently-used order (front = coldest); each grows by rank-1
    /// appends across rounds and is dropped when its prefix breaks
    /// (windowing) or the cache overflows.
    chol_cache: Vec<CholeskyState>,
    /// Shared squared-distance matrix over the current observation window.
    dist_cache: Option<DistCache>,
    /// Full distance-matrix builds performed (test introspection: the LML
    /// grid must amortize to one build per window, not one per grid point).
    dist_builds: usize,
    /// Incremental distance appends performed (test introspection).
    dist_appends: usize,
    /// Incrementally encoded history rows.
    enc_cache: EncodeCache,
    /// Iterations seen (drives the adaptive beta schedule).
    pub rounds: usize,
}

impl BayesianCore {
    pub fn new(space: SearchSpace, opts: GpOptions) -> Result<Self> {
        let surrogate: Box<dyn Surrogate> = match opts.backend {
            SurrogateBackend::Native => Box::new(NativeGp),
            SurrogateBackend::Pjrt => Box::new(PjrtSurrogate::from_default_artifacts()?),
        };
        let encoder = Encoder::new(&space);
        Ok(Self {
            space,
            encoder,
            opts,
            surrogate,
            chol_cache: Vec::new(),
            dist_cache: None,
            dist_builds: 0,
            dist_appends: 0,
            enc_cache: EncodeCache::default(),
            rounds: 0,
        })
    }

    /// Max observations the surrogate can hold, answered by the backend
    /// itself ([`Surrogate::max_obs`]) — the PJRT backend reads its loaded
    /// artifact manifest, so this can never drift from the actual artifact
    /// capacity the way a hardcoded mirror could.
    pub fn max_obs(&self) -> usize {
        self.surrogate.max_obs()
    }

    /// Encode history into a padded-free (n x d) matrix, re-using the
    /// encoded rows of the longest shared leading-config prefix (encoding
    /// is deterministic, so reuse is bitwise-transparent) and encoding
    /// only the appended tail.
    fn encode_history(&mut self, history: &History) -> Matrix {
        let d = self.encoder.dims();
        let n = history.len();
        let cfgs = history.configs();
        let cache = &mut self.enc_cache;
        let max = cache.configs.len().min(n);
        let q = (0..max).take_while(|&i| cache.configs[i] == cfgs[i]).count();
        cache.configs.truncate(q);
        cache.flat.truncate(q * d);
        for cfg in &cfgs[q..] {
            let start = cache.flat.len();
            cache.flat.resize(start + d, 0.0);
            self.encoder.encode_into(cfg, &mut cache.flat[start..]);
            cache.configs.push(cfg.clone());
        }
        Matrix::from_vec(n, d, cache.flat.clone())
    }

    /// Bring the shared squared-distance cache up to date with `x`
    /// (append-only prefix reuse; truncate-and-regrow on a divergent tail;
    /// full rebuild on a broken prefix).
    fn update_dist_cache(&mut self, x: &Matrix) {
        let n = x.rows();
        let q = self.dist_cache.as_ref().map_or(0, |c| {
            if c.x.cols() != x.cols() {
                return 0;
            }
            let max = c.x.rows().min(n);
            (0..max).take_while(|&r| c.x.row(r) == x.row(r)).count()
        });
        if q == 0 {
            // Window slide / first build: one GEMM-based distance build.
            let norms = kernel::row_sq_norms(x);
            let d2 = kernel::sq_dists(x, x);
            self.dist_cache = Some(DistCache { x: x.clone(), norms, d2 });
            self.dist_builds += 1;
            return;
        }
        let cache = self.dist_cache.as_mut().expect("q > 0 implies a cache");
        if q == cache.x.rows() && q == n {
            return; // same window, nothing to do
        }
        // Truncate to the shared prefix, then append rows q..n. Each new
        // entry uses the same parts arithmetic as a fresh `sq_dists` build
        // (norms via the sequential dot, cross terms via `dot`), so the
        // grown matrix is bit-identical to a from-scratch one.
        cache.norms.truncate(q);
        for r in q..n {
            cache.norms.push(crate::linalg::dot(x.row(r), x.row(r)));
        }
        let old = &cache.d2;
        let norms = &cache.norms;
        let d2 = Matrix::from_fn(n, n, |i, j| {
            if i < q && j < q {
                old[(i, j)]
            } else {
                kernel::sq_dist_from_parts(
                    norms[i],
                    norms[j],
                    crate::linalg::dot(x.row(i), x.row(j)),
                )
            }
        });
        cache.d2 = d2;
        cache.x = x.clone();
        self.dist_appends += 1;
    }

    /// Fit through the Cholesky cache: pop the state matching `params`
    /// (refreshing its recency), extend it (or rebuild on a stale prefix),
    /// and push it back as most-recently-used; the least-recently-used
    /// state is evicted on overflow. Isotropic fits are routed through the
    /// shared squared-distance cache when it covers `x` — a pure
    /// precomputation (bit-identical fits), so the LML grid pays one
    /// distance build plus an elementwise `exp` map per grid point.
    fn fit_cached(&mut self, x: &Matrix, y: &[f64], params: &GpParams) -> Result<FitOut> {
        let state = self
            .chol_cache
            .iter()
            .position(|s| s.matches_params(params))
            // remove(i), not swap_remove: the cache is kept in LRU order
            // (front = coldest), which swap_remove would scramble — the
            // old scheme could evict the fixed-default key while hot grid
            // keys churned.
            .map(|i| self.chol_cache.remove(i));
        let sq_dists = if kernel::iso_inv_ls(&params.inv_lengthscale, x.cols()).is_some() {
            self.dist_cache.as_ref().filter(|c| c.x == *x).map(|c| &c.d2)
        } else {
            None
        };
        let (fit, state) = self.surrogate.fit_incremental_shared(x, y, params, state, sq_dists)?;
        if self.chol_cache.len() >= CHOL_CACHE_MAX {
            self.chol_cache.remove(0); // least-recently-used key
        }
        self.chol_cache.push(state);
        Ok(fit)
    }

    /// Effective candidate-scoring thread count (0 = one per core).
    fn scoring_threads(&self) -> usize {
        match self.opts.proposal_threads {
            0 => std::thread::available_parallelism().map_or(1, |n| n.get()),
            t => t,
        }
    }

    /// Fit the surrogate and score an MC candidate set.
    ///
    /// `batch_size` feeds the adaptive beta (paper: exploration depends on
    /// batch size); `rng` drives candidate sampling and (if enabled) the
    /// lengthscale grid.
    pub fn fit_and_score(
        &mut self,
        history: &History,
        batch_size: usize,
        rng: &mut Pcg64,
    ) -> Result<Scored> {
        let x_obs = self.encode_history(history);
        // One shared squared-distance build per round feeds every fit
        // below (all five LML grid points derive their Gram from it) —
        // skipped entirely for backends whose compiled kernel would
        // discard the hint.
        if self.surrogate.consumes_shared_dists() {
            self.update_dist_cache(&x_obs);
        }
        let yn = match self.opts.y_transform {
            YTransform::Normalize => normalize_y(history.values()).0,
            YTransform::RankGauss => acq::rank_gauss(history.values()),
        };
        let d = self.encoder.dims();

        let beta = self.opts.fixed_beta.unwrap_or_else(|| {
            acq::adaptive_beta(self.rounds, self.space.cardinality_estimate(), batch_size)
        });
        self.rounds += 1;

        // Lengthscale: fixed default or LML grid search (paper: Mango
        // internally selects GP hyperparameters). Each grid point keeps its
        // own cached Cholesky state, so the whole grid stays incremental.
        let mut params = GpParams::new(d).with_beta(beta);
        params.noise = self.opts.noise;
        let fit = if self.opts.tune_lengthscale {
            let mut best: Option<(f64, GpParams, FitOut)> = None;
            for ls in LML_LENGTHSCALE_GRID {
                let mut p = GpParams::new(d).with_beta(beta).with_lengthscale(ls);
                p.noise = self.opts.noise;
                let f = self.fit_cached(&x_obs, &yn, &p)?;
                let lml = f.log_marginal_likelihood(&yn);
                if best.as_ref().map_or(true, |(b, _, _)| lml > *b) {
                    best = Some((lml, p, f));
                }
            }
            let (_, p, f) = best.unwrap();
            params = p;
            f
        } else {
            self.fit_cached(&x_obs, &yn, &params)?
        };

        // Columnar candidate generation: values drawn in the legacy RNG
        // sequence, written straight into typed columns + the encoded
        // matrix — no per-candidate Config exists at any point.
        let mut cands = acq::mc_candidates(&self.space, self.opts.mc_samples, rng);
        let xc = cands.take_encoded_matrix();
        debug_assert_eq!(xc.cols(), d);
        // Candidate scoring dominates the propose step (m ≫ n). Native
        // backend: local chunked scoring across `proposal_threads` scoped
        // workers, or — with `proposal_shards` ≥ 1 — fixed chunks shipped
        // as jobs through the scheduler's worker-pool machinery
        // (gp::acquire_sharded). Both are byte-identical to a single pass
        // for every setting. Artifact backends keep their own chunked
        // execution model.
        let acq_out = match self.opts.backend {
            SurrogateBackend::Native if self.opts.proposal_shards > 0 => gp::acquire_sharded(
                &x_obs,
                &fit,
                &xc,
                &params,
                self.opts.proposal_shards,
                self.scoring_threads(),
                &self.opts.shard_exec,
                // Round counter as the fate salt: the simulated cluster's
                // fault sequence evolves per propose round instead of
                // replaying one schedule forever (wall-clock only — the
                // scored output is salt-independent).
                self.rounds as u64,
            )?,
            SurrogateBackend::Native => {
                gp::acquire_parallel(&x_obs, &fit, &xc, &params, self.scoring_threads())?
            }
            SurrogateBackend::Pjrt => self.surrogate.acquire(&x_obs, &fit, &xc, &params)?,
        };
        Ok(Scored { x_obs, cands, xc, acq: acq_out, params })
    }

    pub fn backend_name(&self) -> &'static str {
        self.surrogate.name()
    }

    /// The cached [`CholeskyState`] matching `params`' kernel key, if any —
    /// introspection for the recovery tests (resume-rebuilt factor must be
    /// bit-identical to the uninterrupted run's).
    pub fn cached_state(&self, params: &GpParams) -> Option<&CholeskyState> {
        self.chol_cache.iter().find(|s| s.matches_params(params))
    }

    /// Restore state after a journal replay: set the adaptive-beta clock to
    /// the journaled `rounds` and warm the incremental Cholesky cache over
    /// the replayed history window, so the first post-resume fit pays the
    /// O(kn²) append path instead of an O(n³) from-scratch refactorization
    /// per kernel key. The warm-up itself is one factorization pass (O(n²)
    /// per replayed row — the same per-observation cost the uninterrupted
    /// run paid), and by the append/scratch equivalence property the
    /// resulting factor is bit-identical to the state the crashed process
    /// held over the same rows. With lengthscale tuning enabled every grid
    /// point is warmed, mirroring `fit_and_score`'s per-grid-point caches.
    pub fn rehydrate(&mut self, history: &History, rounds: usize) -> Result<()> {
        self.rounds = rounds;
        if history.is_empty() {
            return Ok(());
        }
        let x_obs = self.encode_history(history);
        if self.surrogate.consumes_shared_dists() {
            self.update_dist_cache(&x_obs);
        }
        let yn = match self.opts.y_transform {
            YTransform::Normalize => normalize_y(history.values()).0,
            YTransform::RankGauss => acq::rank_gauss(history.values()),
        };
        let d = self.encoder.dims();
        if self.opts.tune_lengthscale {
            for ls in LML_LENGTHSCALE_GRID {
                let mut p = GpParams::new(d).with_lengthscale(ls);
                p.noise = self.opts.noise;
                self.fit_cached(&x_obs, &yn, &p)?;
            }
        } else {
            let mut p = GpParams::new(d);
            p.noise = self.opts.noise;
            self.fit_cached(&x_obs, &yn, &p)?;
        }
        Ok(())
    }

    /// [`rehydrate`](Self::rehydrate) for an async resume with configs
    /// still in flight: warms the cache over the constant-liar augmented
    /// view `[history + pending]` — the exact matrix the first post-resume
    /// liar fit covers (built by the same [`super::liar_augmented`] the
    /// propose path uses), so that fit pays the append path instead of a
    /// from-scratch refactorization. With no pending work this is plain
    /// `rehydrate`.
    pub fn rehydrate_pending(
        &mut self,
        history: &History,
        pending: &[Config],
        rounds: usize,
    ) -> Result<()> {
        if pending.is_empty() {
            return self.rehydrate(history, rounds);
        }
        let augmented = super::liar_augmented(history, pending, self.max_obs());
        self.rehydrate(&augmented, rounds)
    }

    /// Full distance-matrix builds performed so far (test introspection:
    /// the shared-distance grid amortizes to one build per window).
    pub fn dist_matrix_builds(&self) -> usize {
        self.dist_builds
    }

    /// Incremental distance-row appends performed so far.
    pub fn dist_matrix_appends(&self) -> usize {
        self.dist_appends
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::svm_space;

    fn history_from(space: &SearchSpace, n: usize, seed: u64) -> History {
        let mut rng = Pcg64::new(seed);
        let mut h = History::new();
        for cfg in space.sample_n(&mut rng, n) {
            let v = -(cfg.get_f64("c").unwrap() - 50.0).abs();
            h.push(cfg, v);
        }
        h
    }

    #[test]
    fn fit_and_score_shapes() {
        let space = svm_space();
        let mut core = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        let h = history_from(&space, 12, 3);
        let mut rng = Pcg64::new(4);
        let s = core.fit_and_score(&h, 1, &mut rng).unwrap();
        assert_eq!(s.x_obs.rows(), 12);
        assert_eq!(s.cands.len(), s.xc.rows());
        assert_eq!(s.acq.ucb.len(), s.cands.len());
        assert_eq!(s.acq.w.rows(), 12);
        // Winner materialization works after the encoded matrix moved out.
        assert_eq!(s.cands.config(0).len(), 2);
    }

    #[test]
    fn rounds_advance_beta() {
        let space = svm_space();
        let mut core = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        let h = history_from(&space, 8, 5);
        let mut rng = Pcg64::new(6);
        let s1 = core.fit_and_score(&h, 1, &mut rng).unwrap();
        let s2 = core.fit_and_score(&h, 1, &mut rng).unwrap();
        assert!(s2.params.beta >= s1.params.beta);
        assert_eq!(core.rounds, 2);
    }

    #[test]
    fn fixed_beta_respected() {
        let space = svm_space();
        let opts = GpOptions { fixed_beta: Some(1.7), ..Default::default() };
        let mut core = BayesianCore::new(space.clone(), opts).unwrap();
        let h = history_from(&space, 8, 5);
        let mut rng = Pcg64::new(6);
        let s = core.fit_and_score(&h, 4, &mut rng).unwrap();
        assert_eq!(s.params.beta, 1.7);
    }

    #[test]
    fn lengthscale_tuning_runs() {
        let space = svm_space();
        let opts = GpOptions { tune_lengthscale: true, ..Default::default() };
        let mut core = BayesianCore::new(space.clone(), opts).unwrap();
        let h = history_from(&space, 15, 8);
        let mut rng = Pcg64::new(9);
        let s = core.fit_and_score(&h, 1, &mut rng).unwrap();
        let ls = 1.0 / s.params.inv_lengthscale[0];
        assert!(LML_LENGTHSCALE_GRID.iter().any(|&v| (ls - v).abs() < 1e-9));
    }

    #[test]
    fn max_obs_answers_from_the_backend() {
        let space = svm_space();
        let native = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        assert_eq!(native.max_obs(), usize::MAX, "native GP is unbounded");
        let opts = GpOptions { backend: SurrogateBackend::Pjrt, ..Default::default() };
        let pjrt = BayesianCore::new(space, opts).unwrap();
        // Must equal whatever the surrogate reports (manifest capacity, or
        // the fallback default when no artifacts are on disk) — not a
        // hardcoded optimizer-side constant.
        assert!(pjrt.max_obs() < usize::MAX, "pjrt artifacts are bounded");
        assert!(pjrt.max_obs() >= 128);
    }

    /// The Cholesky cache must be a pure optimization: a core that reuses
    /// its state across growing-history rounds produces *exactly* the same
    /// scores as a fresh core fitting from scratch (the append path is
    /// bit-identical arithmetic).
    #[test]
    fn chol_cache_matches_fresh_fits_exactly() {
        let space = svm_space();
        let opts = GpOptions { fixed_beta: Some(2.0), ..Default::default() };
        let h1 = history_from(&space, 10, 21);
        let mut h2 = h1.clone();
        for cfg in space.sample_n(&mut Pcg64::new(22), 3) {
            let v = -(cfg.get_f64("c").unwrap() - 50.0).abs();
            h2.push(cfg, v);
        }

        let mut warm = BayesianCore::new(space.clone(), opts.clone()).unwrap();
        warm.fit_and_score(&h1, 1, &mut Pcg64::new(30)).unwrap(); // primes the cache
        let s_warm = warm.fit_and_score(&h2, 1, &mut Pcg64::new(31)).unwrap();

        let mut fresh = BayesianCore::new(space, opts).unwrap();
        let s_fresh = fresh.fit_and_score(&h2, 1, &mut Pcg64::new(31)).unwrap();

        assert_eq!(s_warm.acq.mean, s_fresh.acq.mean);
        assert_eq!(s_warm.acq.var, s_fresh.acq.var);
        assert_eq!(s_warm.acq.ucb, s_fresh.acq.ucb);
    }

    /// Windowing (`truncate_to_recent` / `recent`) breaks the cached
    /// prefix; the refit must be transparent and exact.
    #[test]
    fn window_shrink_invalidates_cache_transparently() {
        let space = svm_space();
        let opts = GpOptions { fixed_beta: Some(2.0), ..Default::default() };
        let h = history_from(&space, 14, 23);
        let shrunk = h.recent(9); // drops the 5 oldest observations

        let mut warm = BayesianCore::new(space.clone(), opts.clone()).unwrap();
        warm.fit_and_score(&h, 1, &mut Pcg64::new(40)).unwrap();
        let s_warm = warm.fit_and_score(&shrunk, 1, &mut Pcg64::new(41)).unwrap();

        let mut fresh = BayesianCore::new(space, opts).unwrap();
        let s_fresh = fresh.fit_and_score(&shrunk, 1, &mut Pcg64::new(41)).unwrap();

        assert_eq!(s_warm.acq.mean, s_fresh.acq.mean);
        assert_eq!(s_warm.acq.var, s_fresh.acq.var);
        assert_eq!(s_warm.acq.ucb, s_fresh.acq.ucb);
    }

    /// One shared squared-distance matrix per round feeds all five LML
    /// grid points, and append-only growth reuses it incrementally — the
    /// grid's kernel-build cost amortizes from 5 O(n²d) builds per round
    /// to 1 per *window*, plus elementwise exp maps.
    #[test]
    fn lml_grid_shares_one_distance_matrix_across_rounds() {
        let space = svm_space();
        let opts =
            GpOptions { tune_lengthscale: true, fixed_beta: Some(2.0), ..Default::default() };
        let mut core = BayesianCore::new(space.clone(), opts).unwrap();
        let h = history_from(&space, 14, 31);
        let prefix = |n: usize| {
            let mut p = History::new();
            for i in 0..n {
                p.push(h.configs()[i].clone(), h.values()[i]);
            }
            p
        };
        let mut rng = Pcg64::new(60);

        // Round 1 over the first 10 rows: one build despite 5 grid fits.
        core.fit_and_score(&prefix(10), 1, &mut rng).unwrap();
        assert_eq!(core.dist_matrix_builds(), 1, "grid must share one distance build");
        assert_eq!(core.dist_matrix_appends(), 0);

        // Round 2, append-only growth to 14 rows: no new build, one append.
        core.fit_and_score(&h, 1, &mut rng).unwrap();
        assert_eq!(core.dist_matrix_builds(), 1, "append-only growth must not rebuild");
        assert_eq!(core.dist_matrix_appends(), 1);

        // Same window again: cache untouched.
        core.fit_and_score(&h, 1, &mut rng).unwrap();
        assert_eq!(core.dist_matrix_builds(), 1);
        assert_eq!(core.dist_matrix_appends(), 1);

        // Window slide (drops the oldest rows): prefix broken, one rebuild.
        core.fit_and_score(&h.recent(9), 1, &mut rng).unwrap();
        assert_eq!(core.dist_matrix_builds(), 2, "window slide pays one rebuild");
    }

    /// The Cholesky cache must be *most-recently-used* ordered: reusing a
    /// key refreshes its recency, and overflow evicts the coldest key —
    /// never a just-touched one. (Regression: the old swap_remove +
    /// remove(0) scheme scrambled the order and could evict the
    /// fixed-default key while grid keys churned.)
    #[test]
    fn chol_cache_eviction_is_true_lru() {
        let space = svm_space();
        let mut core = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        let h = history_from(&space, 8, 41);
        let mut rng = Pcg64::new(70);
        let s = core.fit_and_score(&h, 1, &mut rng).unwrap(); // builds x/dist caches
        let x = s.x_obs.clone();
        let y = vec![0.0; x.rows()];
        let (d, noise) = (x.cols(), core.opts.noise);
        let key = move |ls: f64| {
            let mut p = GpParams::new(d).with_lengthscale(ls);
            p.noise = noise;
            p
        };
        core.chol_cache.clear();
        // Fill the cache to capacity: the "default" key first, then grid-
        // like churn keys (all distinct lengthscales).
        let default_ls = 0.31;
        let churn: Vec<f64> = (0..CHOL_CACHE_MAX - 1).map(|i| 0.4 + 0.07 * i as f64).collect();
        core.fit_cached(&x, &y, &key(default_ls)).unwrap();
        for &ls in &churn {
            core.fit_cached(&x, &y, &key(ls)).unwrap();
        }
        assert_eq!(core.chol_cache.len(), CHOL_CACHE_MAX);
        // A full churn round re-touches every grid key, then the default:
        // recency order must now be [churn..., default].
        for &ls in &churn {
            core.fit_cached(&x, &y, &key(ls)).unwrap();
        }
        core.fit_cached(&x, &y, &key(default_ls)).unwrap();
        assert_eq!(core.chol_cache.len(), CHOL_CACHE_MAX, "touches must not grow the cache");
        assert!(
            core.cached_state(&key(default_ls)).is_some(),
            "default key must survive a full churn round"
        );
        // Overflow with a brand-new key: the true LRU (churn[0]) is
        // evicted; the just-touched default key survives.
        core.fit_cached(&x, &y, &key(0.97)).unwrap();
        assert_eq!(core.chol_cache.len(), CHOL_CACHE_MAX);
        assert!(
            core.cached_state(&key(churn[0])).is_none(),
            "the least-recently-used key must be the one evicted"
        );
        assert!(
            core.cached_state(&key(default_ls)).is_some(),
            "a just-touched key must never be evicted by churn"
        );
        assert!(core.cached_state(&key(0.97)).is_some());
    }

    /// The deterministic-parallel-scoring contract at the optimizer level:
    /// `fit_and_score` output is byte-identical for every
    /// `proposal_threads` setting (including 0 = auto).
    #[test]
    fn fit_and_score_is_byte_identical_across_proposal_threads() {
        let space = svm_space();
        let h = history_from(&space, 12, 51);
        let run = |threads: usize| {
            let opts = GpOptions {
                proposal_threads: threads,
                fixed_beta: Some(2.0),
                mc_samples: 257, // odd: ragged chunk boundaries
                ..Default::default()
            };
            let mut core = BayesianCore::new(space.clone(), opts).unwrap();
            core.fit_and_score(&h, 1, &mut Pcg64::new(80)).unwrap()
        };
        let base = run(1);
        for threads in [2usize, 8, 0] {
            let s = run(threads);
            assert_eq!(s.xc, base.xc, "{threads}: candidate set differs");
            assert_eq!(s.cands.column(0), base.cands.column(0), "{threads}: columns differ");
            assert_eq!(s.acq.ucb, base.acq.ucb, "{threads} threads: ucb deviates");
            assert_eq!(s.acq.mean, base.acq.mean, "{threads} threads: mean deviates");
            assert_eq!(s.acq.var, base.acq.var, "{threads} threads: var deviates");
            assert_eq!(s.acq.w, base.acq.w, "{threads} threads: w deviates");
        }
    }

    /// The sharded-scoring contract at the optimizer level: `fit_and_score`
    /// output is byte-identical across every `proposal_shards` ∈ {0, 1, 3}
    /// × scheduler-kind (serial / threaded / celery-sim with its fault
    /// fates firing) × `proposal_threads` setting. `proposal_shards = 0`
    /// is the local-only path — today's behavior byte-for-byte.
    #[test]
    fn fit_and_score_is_byte_identical_across_proposal_shards_and_schedulers() {
        use crate::gp::ShardExec;
        let space = svm_space();
        let h = history_from(&space, 11, 52);
        let faulty = crate::scheduler::celery::CelerySimConfig {
            workers: 2,
            base_latency_ms: 0.05,
            straggler_prob: 0.3,
            straggler_factor: 1000.0,
            crash_prob: 0.3,
            result_timeout: std::time::Duration::from_millis(2),
        };
        let run = |shards: usize, threads: usize, exec: ShardExec| {
            let opts = GpOptions {
                proposal_shards: shards,
                proposal_threads: threads,
                shard_exec: exec,
                fixed_beta: Some(2.0),
                mc_samples: 193, // odd: ragged shard boundaries
                ..Default::default()
            };
            let mut core = BayesianCore::new(space.clone(), opts).unwrap();
            core.fit_and_score(&h, 1, &mut Pcg64::new(81)).unwrap()
        };
        let base = run(0, 1, ShardExec::Serial);
        for shards in [0usize, 1, 3] {
            for threads in [1usize, 2] {
                for exec in [
                    ShardExec::Serial,
                    ShardExec::Threaded,
                    ShardExec::CelerySim { config: faulty.clone(), seed: 7 },
                ] {
                    let tag = format!("shards={shards} threads={threads} {exec:?}");
                    let s = run(shards, threads, exec);
                    assert_eq!(s.xc, base.xc, "{tag}: candidate set differs");
                    assert_eq!(s.acq.ucb, base.acq.ucb, "{tag}: ucb deviates");
                    assert_eq!(s.acq.mean, base.acq.mean, "{tag}: mean deviates");
                    assert_eq!(s.acq.var, base.acq.var, "{tag}: var deviates");
                    assert_eq!(s.acq.w, base.acq.w, "{tag}: w deviates");
                }
            }
        }
    }

    /// Satellite: `rehydrate_pending` must warm the cache over the exact
    /// constant-liar view the first post-resume fit covers — bit-identical
    /// to the state a live (uninterrupted) core holds after fitting the
    /// same augmented history.
    #[test]
    fn rehydrate_pending_warms_the_liar_fit_state() {
        let space = svm_space();
        let opts = GpOptions { fixed_beta: Some(2.0), ..Default::default() };
        let h = history_from(&space, 9, 61);
        let mut rng = Pcg64::new(90);
        let pending = space.sample_n(&mut rng, 3);

        // The live core's last pre-crash action: a constant-liar fit over
        // [history + pending].
        let augmented = crate::optimizer::liar_augmented(&h, &pending, usize::MAX);
        let mut live = BayesianCore::new(space.clone(), opts.clone()).unwrap();
        live.fit_and_score(&augmented, 1, &mut Pcg64::new(91)).unwrap();

        // The resumed core warms through rehydrate_pending.
        let mut resumed = BayesianCore::new(space.clone(), opts).unwrap();
        resumed.rehydrate_pending(&h, &pending, 1).unwrap();
        assert_eq!(resumed.rounds, 1);

        let d = Encoder::new(&space).dims();
        let mut params = GpParams::new(d);
        params.noise = GpOptions::default().noise;
        let live_state = live.cached_state(&params).expect("live liar-fit state");
        let warm_state = resumed.cached_state(&params).expect("rehydrated liar state");
        assert_eq!(
            warm_state.rows(),
            h.len() + pending.len(),
            "warm state must cover history + pending, not history alone"
        );
        assert_eq!(
            warm_state.factor(),
            live_state.factor(),
            "warmed factor must be bit-identical to the live liar fit's"
        );
    }

    #[test]
    fn grid_search_keeps_one_state_per_lengthscale() {
        let space = svm_space();
        let opts =
            GpOptions { tune_lengthscale: true, fixed_beta: Some(2.0), ..Default::default() };
        let mut core = BayesianCore::new(space.clone(), opts).unwrap();
        let h = history_from(&space, 10, 25);
        core.fit_and_score(&h, 1, &mut Pcg64::new(50)).unwrap();
        assert_eq!(
            core.chol_cache.len(),
            LML_LENGTHSCALE_GRID.len(),
            "one cached state per grid point"
        );
        // A second round reuses all five without growing the cache.
        core.fit_and_score(&h, 1, &mut Pcg64::new(51)).unwrap();
        assert_eq!(core.chol_cache.len(), LML_LENGTHSCALE_GRID.len());
        assert!(core.chol_cache.iter().all(|s| s.rows() == 10));
    }
}
