//! Shared GP-UCB machinery for the two batch Bayesian algorithms:
//! history encoding, y-normalization, surrogate fitting (with optional
//! lengthscale selection by marginal likelihood), adaptive beta, and
//! Monte-Carlo acquisition scoring.

use super::{GpOptions, History, SurrogateBackend, YTransform};
use crate::acq;
use crate::gp::{normalize_y, AcquireOut, GpParams, NativeGp, Surrogate};
use crate::linalg::Matrix;
use crate::runtime::PjrtSurrogate;
use crate::space::{Config, Encoder, SearchSpace};
use crate::util::rng::Pcg64;
use anyhow::Result;

/// One fit-and-score round over the history: everything a batch-selection
/// strategy needs.
pub struct Scored {
    /// Encoded observation matrix (n x d).
    pub x_obs: Matrix,
    /// Candidate configurations (the MC sample).
    pub candidates: Vec<Config>,
    /// Encoded candidates (m x d).
    pub xc: Matrix,
    pub acq: AcquireOut,
    pub params: GpParams,
}

pub struct BayesianCore {
    pub space: SearchSpace,
    pub encoder: Encoder,
    pub opts: GpOptions,
    surrogate: Box<dyn Surrogate>,
    /// Iterations seen (drives the adaptive beta schedule).
    pub rounds: usize,
}

impl BayesianCore {
    pub fn new(space: SearchSpace, opts: GpOptions) -> Result<Self> {
        let surrogate: Box<dyn Surrogate> = match opts.backend {
            SurrogateBackend::Native => Box::new(NativeGp),
            SurrogateBackend::Pjrt => Box::new(PjrtSurrogate::from_default_artifacts()?),
        };
        let encoder = Encoder::new(&space);
        Ok(Self { space, encoder, opts, surrogate, rounds: 0 })
    }

    /// Max observations the surrogate can hold (PJRT artifacts are bounded).
    pub fn max_obs(&self) -> usize {
        // Mirror of PjrtSurrogate::max_obs without downcasting: the largest
        // artifact variant. Native has no limit.
        match self.opts.backend {
            SurrogateBackend::Native => usize::MAX,
            SurrogateBackend::Pjrt => 512,
        }
    }

    /// Encode history into a padded-free (n x d) matrix.
    fn encode_history(&self, history: &History) -> Matrix {
        let d = self.encoder.dims();
        let flat = self.encoder.encode_batch(history.configs());
        Matrix::from_vec(history.len(), d, flat)
    }

    /// Fit the surrogate and score an MC candidate set.
    ///
    /// `batch_size` feeds the adaptive beta (paper: exploration depends on
    /// batch size); `rng` drives candidate sampling and (if enabled) the
    /// lengthscale grid.
    pub fn fit_and_score(
        &mut self,
        history: &History,
        batch_size: usize,
        rng: &mut Pcg64,
    ) -> Result<Scored> {
        let x_obs = self.encode_history(history);
        let yn = match self.opts.y_transform {
            YTransform::Normalize => normalize_y(history.values()).0,
            YTransform::RankGauss => acq::rank_gauss(history.values()),
        };
        let d = self.encoder.dims();

        let beta = self.opts.fixed_beta.unwrap_or_else(|| {
            acq::adaptive_beta(self.rounds, self.space.cardinality_estimate(), batch_size)
        });
        self.rounds += 1;

        // Lengthscale: fixed default or LML grid search (paper: Mango
        // internally selects GP hyperparameters).
        let mut params = GpParams::new(d).with_beta(beta);
        params.noise = self.opts.noise;
        let fit = if self.opts.tune_lengthscale {
            let mut best: Option<(f64, GpParams, crate::gp::FitOut)> = None;
            for ls in [0.1, 0.2, 0.3, 0.5, 0.8] {
                let p = GpParams::new(d).with_beta(beta).with_lengthscale(ls);
                let f = self.surrogate.fit(&x_obs, &yn, &p)?;
                let lml = f.log_marginal_likelihood(&yn);
                if best.as_ref().map_or(true, |(b, _, _)| lml > *b) {
                    best = Some((lml, p, f));
                }
            }
            let (_, p, f) = best.unwrap();
            params = p;
            f
        } else {
            self.surrogate.fit(&x_obs, &yn, &params)?
        };

        let candidates = acq::mc_candidates(&self.space, self.opts.mc_samples, rng);
        let flat = self.encoder.encode_batch(&candidates);
        let xc = Matrix::from_vec(candidates.len(), d, flat);
        let acq_out = self.surrogate.acquire(&x_obs, &fit, &xc, &params)?;
        Ok(Scored { x_obs, candidates, xc, acq: acq_out, params })
    }

    pub fn backend_name(&self) -> &'static str {
        self.surrogate.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::svm_space;

    fn history_from(space: &SearchSpace, n: usize, seed: u64) -> History {
        let mut rng = Pcg64::new(seed);
        let mut h = History::new();
        for cfg in space.sample_n(&mut rng, n) {
            let v = -(cfg.get_f64("c").unwrap() - 50.0).abs();
            h.push(cfg, v);
        }
        h
    }

    #[test]
    fn fit_and_score_shapes() {
        let space = svm_space();
        let mut core = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        let h = history_from(&space, 12, 3);
        let mut rng = Pcg64::new(4);
        let s = core.fit_and_score(&h, 1, &mut rng).unwrap();
        assert_eq!(s.x_obs.rows(), 12);
        assert_eq!(s.candidates.len(), s.xc.rows());
        assert_eq!(s.acq.ucb.len(), s.candidates.len());
        assert_eq!(s.acq.w.rows(), 12);
    }

    #[test]
    fn rounds_advance_beta() {
        let space = svm_space();
        let mut core = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        let h = history_from(&space, 8, 5);
        let mut rng = Pcg64::new(6);
        let s1 = core.fit_and_score(&h, 1, &mut rng).unwrap();
        let s2 = core.fit_and_score(&h, 1, &mut rng).unwrap();
        assert!(s2.params.beta >= s1.params.beta);
        assert_eq!(core.rounds, 2);
    }

    #[test]
    fn fixed_beta_respected() {
        let space = svm_space();
        let opts = GpOptions { fixed_beta: Some(1.7), ..Default::default() };
        let mut core = BayesianCore::new(space.clone(), opts).unwrap();
        let h = history_from(&space, 8, 5);
        let mut rng = Pcg64::new(6);
        let s = core.fit_and_score(&h, 4, &mut rng).unwrap();
        assert_eq!(s.params.beta, 1.7);
    }

    #[test]
    fn lengthscale_tuning_runs() {
        let space = svm_space();
        let opts = GpOptions { tune_lengthscale: true, ..Default::default() };
        let mut core = BayesianCore::new(space.clone(), opts).unwrap();
        let h = history_from(&space, 15, 8);
        let mut rng = Pcg64::new(9);
        let s = core.fit_and_score(&h, 1, &mut rng).unwrap();
        let ls = 1.0 / s.params.inv_lengthscale[0];
        assert!([0.1, 0.2, 0.3, 0.5, 0.8].iter().any(|&v| (ls - v).abs() < 1e-9));
    }
}
