//! Shared GP-UCB machinery for the two batch Bayesian algorithms:
//! history encoding, y-normalization, incremental surrogate fitting (with
//! optional lengthscale selection by marginal likelihood), adaptive beta,
//! and Monte-Carlo acquisition scoring.
//!
//! Fits are *incremental*: [`BayesianCore`] keeps a persistent
//! [`CholeskyState`] per kernel-hyperparameter key, so each scheduling
//! round only pays O(n²) per new observation instead of an O(n³) refit
//! (the tuner's surrogate step stays cheap relative to trial evaluation —
//! the property Tune and Sherpa both call out as essential for parallel
//! tuning to scale). A state is reused only while the history window grows
//! append-only; `truncate_to_recent` windowing or a lengthscale retune
//! transparently fall back to one from-scratch factorization.

use super::{GpOptions, History, SurrogateBackend, YTransform};
use crate::acq;
use crate::gp::{
    self, kernel, normalize_y, AcquireOut, CholeskyState, FitOut, GpParams, NativeGp, Surrogate,
};
use crate::linalg::Matrix;
use crate::runtime::PjrtSurrogate;
use crate::space::{ColumnarSet, Config, Encoder, SearchSpace};
use crate::util::rng::Pcg64;
use anyhow::Result;

/// The LML lengthscale grid (unit-cube lengthscales probed per fit when
/// `tune_lengthscale` is on). Shared by `fit_and_score` and `rehydrate` so
/// recovery warms exactly the cache entries the grid search will hit.
pub const LML_LENGTHSCALE_GRID: [f64; 5] = [0.1, 0.2, 0.3, 0.5, 0.8];

/// Upper bound on cached Cholesky states: the LML grid search probes the
/// grid's 5 fixed lengthscales; +1 covers the fixed-default parameters.
const CHOL_CACHE_MAX: usize = LML_LENGTHSCALE_GRID.len() + 1;

/// One fit-and-score round over the history: everything a batch-selection
/// strategy needs.
///
/// The MC candidate set is **columnar** ([`ColumnarSet`]): typed SoA
/// columns instead of `m` materialized `Config`s — a batch-selection
/// strategy materializes only its ≤ batch-size winners via
/// [`ColumnarSet::config`].
pub struct Scored {
    /// Encoded observation matrix (n x d).
    pub x_obs: Matrix,
    /// The MC candidate set in columnar form (its encoded matrix has been
    /// moved out into [`Scored::xc`]).
    pub cands: ColumnarSet,
    /// Encoded candidates (m x d).
    pub xc: Matrix,
    pub acq: AcquireOut,
    pub params: GpParams,
}

/// Cached pairwise squared distances over the encoded observation rows —
/// the *unscaled* D² every isotropic kernel build derives its Gram from
/// (`exp(−0.5·il²·D²)`), so one matrix per round feeds the whole LML
/// lengthscale grid. Maintained with the same append-only prefix-reuse
/// discipline as the Cholesky cache: one new row per new observation, a
/// divergent tail (async constant-liar fits) truncates to the shared
/// prefix and regrows, and a window slide (prefix 0) rebuilds from
/// scratch. Entries are bit-stable across all three paths
/// ([`kernel::sq_dists`] == `dot`-derived rows, by the `matmul_transb`
/// contract), so feeding the cache into a fit is a pure precomputation.
struct DistCache {
    /// Encoded rows the distances cover.
    x: Matrix,
    /// Row squared norms (sequential-`dot` reduction in the Exact
    /// profile, `dot_fast` in Fast; appendable either way).
    norms: Vec<f64>,
    body: DistBody,
}

/// Storage layout of the cached D², selected by the kernel profile.
enum DistBody {
    /// `Exact` profile: dense symmetric n×n f64 — byte-for-byte the
    /// pre-profile representation and arithmetic.
    Dense(Matrix),
    /// `Fast` profile: the lower triangle in fixed-size tiles.
    Tiled(TiledDistCache),
}

/// Side length of the square tiles the Fast-profile distance cache is
/// stored in. Row blocks are appended/evicted at this granularity.
pub const DIST_TILE: usize = 64;

/// Element type of the tiled cache's slabs. `F32` halves the footprint
/// again (~25% of the dense f64 matrix) at ~1e-7 relative distance error —
/// an opt-in for footprint-bound deployments and the bench's
/// `footprint_bytes` measurements; the Fast hot path defaults to `F64`
/// tiles (~50% footprint) so end-to-end proposals stay within the 1e-10
/// tolerance contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TileElem {
    F64,
    F32,
}

enum TileSlabs {
    F64(Vec<Vec<f64>>),
    F32(Vec<Vec<f32>>),
}

/// The Fast-profile distance cache: the lower triangle of the symmetric
/// pairwise-D² matrix, stored as [`DIST_TILE`]² tiles grouped by row
/// block. Row block `b` covers global rows `[b·T, min((b+1)·T, n))` and
/// holds one contiguous slab of `(b+1)` tiles — tiles `(b, 0..=b)` — so
/// the per-core footprint is ~50% of the dense f64 matrix (f64 tiles) or
/// ~25% (f32), and the cache grows past any fixed artifact cap one row
/// block at a time.
///
/// Growth reuses the dense cache's prefix-reuse/truncate-and-regrow state
/// machine at *tile* granularity: a verified row prefix of `q` keeps the
/// `q / T` fully-covered row blocks bitwise (appending rows never touches
/// them — new columns against old rows land in the new rows' blocks via
/// the symmetric read), evicts everything past them, and regrows. Every
/// entry is computed with the same `sq_dist_from_parts ∘ dot_fast`
/// arithmetic on every path, so a grown triangle is bit-identical to a
/// from-scratch build over the same rows.
pub struct TiledDistCache {
    elem: TileElem,
    /// Observation rows currently covered.
    n: usize,
    /// Per row block `b`: `(b+1)·T·T` elements, tile `(b, c)` at slab
    /// offset `c·T·T`, entry `(i, j)` at `(i − bT)·T + (j − cT)`. Entries
    /// above the diagonal (inside diagonal tiles) and past row/col `n` are
    /// zero padding — never read.
    slabs: TileSlabs,
}

impl TiledDistCache {
    pub fn new(elem: TileElem) -> Self {
        let slabs = match elem {
            TileElem::F64 => TileSlabs::F64(Vec::new()),
            TileElem::F32 => TileSlabs::F32(Vec::new()),
        };
        Self { elem, n: 0, slabs }
    }

    pub fn rows(&self) -> usize {
        self.n
    }

    fn nblocks(&self) -> usize {
        match &self.slabs {
            TileSlabs::F64(v) => v.len(),
            TileSlabs::F32(v) => v.len(),
        }
    }

    /// Tiles currently held (row block `b` holds `b + 1`).
    pub fn tile_count(&self) -> u64 {
        let nb = self.nblocks() as u64;
        nb * (nb + 1) / 2
    }

    /// Bytes held by the tile slabs — the footprint the tiled mode trades
    /// against the dense n²·8 matrix.
    pub fn footprint_bytes(&self) -> usize {
        match &self.slabs {
            TileSlabs::F64(v) => v.iter().map(|s| s.len()).sum::<usize>() * 8,
            TileSlabs::F32(v) => v.iter().map(|s| s.len()).sum::<usize>() * 4,
        }
    }

    /// Bring the triangle up to date with the `n` rows of `x` given a
    /// verified matching-row prefix of `q` (`q = 0` → full build). Keeps
    /// the `q / T` fully-covered row blocks, evicts every block past them,
    /// and regrows; returns the number of tiles evicted. `norms` must hold
    /// the `dot_fast` row squared norms for all `n` rows.
    pub fn sync(&mut self, x: &Matrix, norms: &[f64], q: usize) -> u64 {
        let t = DIST_TILE;
        let n = x.rows();
        debug_assert_eq!(norms.len(), n);
        let keep = (q / t).min(self.nblocks());
        let dropped: u64 = (keep..self.nblocks()).map(|b| b as u64 + 1).sum();
        match &mut self.slabs {
            TileSlabs::F64(v) => v.truncate(keep),
            TileSlabs::F32(v) => v.truncate(keep),
        }
        for b in keep..n.div_ceil(t) {
            let row_hi = ((b + 1) * t).min(n);
            let mut slab = vec![0.0f64; (b + 1) * t * t];
            for c in 0..=b {
                let col_hi = ((c + 1) * t).min(n);
                let base = c * t * t;
                for i in b * t..row_hi {
                    for j in c * t..col_hi.min(i + 1) {
                        slab[base + (i - b * t) * t + (j - c * t)] = kernel::sq_dist_from_parts(
                            norms[i],
                            norms[j],
                            crate::linalg::dot_fast(x.row(i), x.row(j)),
                        );
                    }
                }
            }
            match &mut self.slabs {
                TileSlabs::F64(v) => v.push(slab),
                TileSlabs::F32(v) => v.push(slab.iter().map(|&e| e as f32).collect()),
            }
        }
        self.n = n;
        dropped
    }

    /// D²(i, j), reading the lower triangle symmetrically (f32 slabs widen
    /// on read).
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let (i, j) = if i >= j { (i, j) } else { (j, i) };
        let t = DIST_TILE;
        let (b, c) = (i / t, j / t);
        let off = c * t * t + (i - b * t) * t + (j - c * t);
        match &self.slabs {
            TileSlabs::F64(v) => v[b][off],
            TileSlabs::F32(v) => v[b][off] as f64,
        }
    }

    /// Materialize the symmetric dense f64 matrix a fit consumes. A
    /// transient per-fit allocation — the persistent footprint stays the
    /// tiled triangle.
    pub fn to_dense(&self) -> Matrix {
        Matrix::from_fn(self.n, self.n, |i, j| self.get(i, j))
    }

    pub fn elem(&self) -> TileElem {
        self.elem
    }
}

/// Incrementally encoded history rows: re-encoding is deterministic, so a
/// shared leading-config prefix re-uses its encoded rows bitwise and only
/// the appended tail is encoded each round.
#[derive(Default)]
struct EncodeCache {
    configs: Vec<Config>,
    flat: Vec<f64>,
}

pub struct BayesianCore {
    pub space: SearchSpace,
    pub encoder: Encoder,
    pub opts: GpOptions,
    surrogate: Box<dyn Surrogate>,
    /// Persistent Cholesky states, one per kernel-hyperparameter key, in
    /// least-recently-used order (front = coldest); each grows by rank-1
    /// appends across rounds and is dropped when its prefix breaks
    /// (windowing) or the cache overflows.
    chol_cache: Vec<CholeskyState>,
    /// Shared squared-distance matrix over the current observation window.
    dist_cache: Option<DistCache>,
    /// Full distance-matrix builds performed (test introspection: the LML
    /// grid must amortize to one build per window, not one per grid point).
    dist_builds: usize,
    /// Incremental distance appends performed (test introspection).
    dist_appends: usize,
    /// Tiles evicted by the Fast profile's truncate-and-regrow (window
    /// slides and divergent liar tails); always 0 in Exact.
    dist_evicts: usize,
    /// Incrementally encoded history rows.
    enc_cache: EncodeCache,
    /// Iterations seen (drives the adaptive beta schedule).
    pub rounds: usize,
}

impl BayesianCore {
    pub fn new(space: SearchSpace, opts: GpOptions) -> Result<Self> {
        let surrogate: Box<dyn Surrogate> = match opts.backend {
            SurrogateBackend::Native => Box::new(NativeGp),
            SurrogateBackend::Pjrt => Box::new(PjrtSurrogate::from_default_artifacts()?),
        };
        let encoder = Encoder::new(&space);
        Ok(Self {
            space,
            encoder,
            opts,
            surrogate,
            chol_cache: Vec::new(),
            dist_cache: None,
            dist_builds: 0,
            dist_appends: 0,
            dist_evicts: 0,
            enc_cache: EncodeCache::default(),
            rounds: 0,
        })
    }

    /// Max observations the surrogate can hold, answered by the backend
    /// itself ([`Surrogate::max_obs`]) — the PJRT backend reads its loaded
    /// artifact manifest, so this can never drift from the actual artifact
    /// capacity the way a hardcoded mirror could.
    pub fn max_obs(&self) -> usize {
        self.surrogate.max_obs()
    }

    /// Encode history into a padded-free (n x d) matrix, re-using the
    /// encoded rows of the longest shared leading-config prefix (encoding
    /// is deterministic, so reuse is bitwise-transparent) and encoding
    /// only the appended tail.
    fn encode_history(&mut self, history: &History) -> Matrix {
        let d = self.encoder.dims();
        let n = history.len();
        let cfgs = history.configs();
        let cache = &mut self.enc_cache;
        let max = cache.configs.len().min(n);
        let q = (0..max).take_while(|&i| cache.configs[i] == cfgs[i]).count();
        cache.configs.truncate(q);
        cache.flat.truncate(q * d);
        for cfg in &cfgs[q..] {
            let start = cache.flat.len();
            cache.flat.resize(start + d, 0.0);
            self.encoder.encode_into(cfg, &mut cache.flat[start..]);
            cache.configs.push(cfg.clone());
        }
        Matrix::from_vec(n, d, cache.flat.clone())
    }

    /// Bring the shared squared-distance cache up to date with `x`
    /// (append-only prefix reuse; truncate-and-regrow on a divergent tail;
    /// full rebuild on a broken prefix). The Exact profile keeps the dense
    /// symmetric matrix and sequential-`dot` arithmetic byte-for-byte; the
    /// Fast profile routes to the tiled triangle.
    fn update_dist_cache(&mut self, x: &Matrix) {
        let fast = self.opts.kernel_profile == gp::KernelProfile::Fast;
        let n = x.rows();
        let q = self.dist_cache.as_ref().map_or(0, |c| {
            if c.x.cols() != x.cols() || matches!(c.body, DistBody::Tiled(_)) != fast {
                return 0;
            }
            let max = c.x.rows().min(n);
            (0..max).take_while(|&r| c.x.row(r) == x.row(r)).count()
        });
        if fast {
            return self.update_dist_cache_tiled(x, q);
        }
        if q == 0 {
            // Window slide / first build: one GEMM-based distance build.
            let norms = kernel::row_sq_norms(x);
            let d2 = kernel::sq_dists(x, x);
            self.dist_cache = Some(DistCache { x: x.clone(), norms, body: DistBody::Dense(d2) });
            self.dist_builds += 1;
            return;
        }
        let cache = self.dist_cache.as_mut().expect("q > 0 implies a cache");
        if q == cache.x.rows() && q == n {
            return; // same window, nothing to do
        }
        // Truncate to the shared prefix, then append rows q..n. Each new
        // entry uses the same parts arithmetic as a fresh `sq_dists` build
        // (norms via the sequential dot, cross terms via `dot`), so the
        // grown matrix is bit-identical to a from-scratch one.
        cache.norms.truncate(q);
        for r in q..n {
            cache.norms.push(crate::linalg::dot(x.row(r), x.row(r)));
        }
        let DistBody::Dense(old) = &cache.body else {
            unreachable!("exact profile always carries a dense body");
        };
        let norms = &cache.norms;
        let d2 = Matrix::from_fn(n, n, |i, j| {
            if i < q && j < q {
                old[(i, j)]
            } else {
                kernel::sq_dist_from_parts(
                    norms[i],
                    norms[j],
                    crate::linalg::dot(x.row(i), x.row(j)),
                )
            }
        });
        cache.body = DistBody::Dense(d2);
        cache.x = x.clone();
        self.dist_appends += 1;
    }

    /// Fast-profile cache maintenance: the same prefix-reuse state machine
    /// at tile-row-block granularity. `q` is the verified matching-row
    /// prefix against the current cache (0 when absent/broken).
    fn update_dist_cache_tiled(&mut self, x: &Matrix, q: usize) {
        let n = x.rows();
        if q == 0 {
            // Full (re)build: whatever the old triangle held is evicted.
            if let Some(DistCache { body: DistBody::Tiled(t), .. }) = &self.dist_cache {
                self.dist_evicts += t.tile_count() as usize;
            }
            let norms: Vec<f64> =
                (0..n).map(|r| crate::linalg::dot_fast(x.row(r), x.row(r))).collect();
            let mut tri = TiledDistCache::new(TileElem::F64);
            tri.sync(x, &norms, 0);
            self.dist_cache =
                Some(DistCache { x: x.clone(), norms, body: DistBody::Tiled(tri) });
            self.dist_builds += 1;
            return;
        }
        let cache = self.dist_cache.as_mut().expect("q > 0 implies a cache");
        if q == cache.x.rows() && q == n {
            return; // same window, nothing to do
        }
        cache.norms.truncate(q);
        for r in q..n {
            cache.norms.push(crate::linalg::dot_fast(x.row(r), x.row(r)));
        }
        let DistBody::Tiled(tri) = &mut cache.body else {
            unreachable!("fast profile always carries a tiled body");
        };
        self.dist_evicts += tri.sync(x, &cache.norms, q) as usize;
        cache.x = x.clone();
        self.dist_appends += 1;
    }

    /// Fit through the Cholesky cache: pop the state matching `params`
    /// (refreshing its recency), extend it (or rebuild on a stale prefix),
    /// and push it back as most-recently-used; the least-recently-used
    /// state is evicted on overflow. Isotropic fits are routed through the
    /// shared squared-distance cache when it covers `x` — a pure
    /// precomputation (bit-identical fits), so the LML grid pays one
    /// distance build plus an elementwise `exp` map per grid point.
    fn fit_cached(&mut self, x: &Matrix, y: &[f64], params: &GpParams) -> Result<FitOut> {
        let state = self
            .chol_cache
            .iter()
            .position(|s| s.matches_params(params))
            // remove(i), not swap_remove: the cache is kept in LRU order
            // (front = coldest), which swap_remove would scramble — the
            // old scheme could evict the fixed-default key while hot grid
            // keys churned.
            .map(|i| self.chol_cache.remove(i));
        let cache_hit = if kernel::iso_inv_ls(&params.inv_lengthscale, x.cols()).is_some() {
            self.dist_cache.as_ref().filter(|c| c.x == *x)
        } else {
            None
        };
        // Tiled triangles materialize a transient dense f64 view per fit;
        // the dense body is borrowed in place (byte-for-byte the old path).
        let tiled_dense = match cache_hit.map(|c| &c.body) {
            Some(DistBody::Tiled(t)) => Some(t.to_dense()),
            _ => None,
        };
        let sq_dists = match cache_hit.map(|c| &c.body) {
            Some(DistBody::Dense(d2)) => Some(d2),
            Some(DistBody::Tiled(_)) => tiled_dense.as_ref(),
            None => None,
        };
        let (fit, state) = self.surrogate.fit_incremental_shared(x, y, params, state, sq_dists)?;
        if self.chol_cache.len() >= CHOL_CACHE_MAX {
            self.chol_cache.remove(0); // least-recently-used key
        }
        self.chol_cache.push(state);
        Ok(fit)
    }

    /// Effective candidate-scoring thread count (0 = one per core).
    fn scoring_threads(&self) -> usize {
        match self.opts.proposal_threads {
            0 => std::thread::available_parallelism().map_or(1, |n| n.get()),
            t => t,
        }
    }

    /// Fit the surrogate and score an MC candidate set.
    ///
    /// `batch_size` feeds the adaptive beta (paper: exploration depends on
    /// batch size); `rng` drives candidate sampling and (if enabled) the
    /// lengthscale grid.
    pub fn fit_and_score(
        &mut self,
        history: &History,
        batch_size: usize,
        rng: &mut Pcg64,
    ) -> Result<Scored> {
        let x_obs = self.encode_history(history);
        // One shared squared-distance build per round feeds every fit
        // below (all five LML grid points derive their Gram from it) —
        // skipped entirely for backends whose compiled kernel would
        // discard the hint.
        if self.surrogate.consumes_shared_dists() {
            self.update_dist_cache(&x_obs);
        }
        let yn = match self.opts.y_transform {
            YTransform::Normalize => normalize_y(history.values()).0,
            YTransform::RankGauss => acq::rank_gauss(history.values()),
        };
        let d = self.encoder.dims();

        let beta = self.opts.fixed_beta.unwrap_or_else(|| {
            acq::adaptive_beta(self.rounds, self.space.cardinality_estimate(), batch_size)
        });
        self.rounds += 1;

        // Lengthscale: fixed default or LML grid search (paper: Mango
        // internally selects GP hyperparameters). Each grid point keeps its
        // own cached Cholesky state, so the whole grid stays incremental.
        let mut params = GpParams::new(d).with_beta(beta);
        params.noise = self.opts.noise;
        let fit = if self.opts.tune_lengthscale {
            let mut best: Option<(f64, GpParams, FitOut)> = None;
            for ls in LML_LENGTHSCALE_GRID {
                let mut p = GpParams::new(d).with_beta(beta).with_lengthscale(ls);
                p.noise = self.opts.noise;
                let f = self.fit_cached(&x_obs, &yn, &p)?;
                let lml = f.log_marginal_likelihood(&yn);
                if best.as_ref().map_or(true, |(b, _, _)| lml > *b) {
                    best = Some((lml, p, f));
                }
            }
            let (_, p, f) = best.unwrap();
            params = p;
            f
        } else {
            self.fit_cached(&x_obs, &yn, &params)?
        };

        // Columnar candidate generation: values drawn in the legacy RNG
        // sequence, written straight into typed columns + the encoded
        // matrix — no per-candidate Config exists at any point.
        let mut cands = acq::mc_candidates(&self.space, self.opts.mc_samples, rng);
        let xc = cands.take_encoded_matrix();
        debug_assert_eq!(xc.cols(), d);
        // Candidate scoring dominates the propose step (m ≫ n). Native
        // backend: local chunked scoring across `proposal_threads` scoped
        // workers, or — with `proposal_shards` ≥ 1 — fixed chunks shipped
        // as jobs through the scheduler's worker-pool machinery
        // (gp::acquire_sharded). Both are byte-identical to a single pass
        // for every setting. Artifact backends keep their own chunked
        // execution model.
        let acq_out = match self.opts.backend {
            SurrogateBackend::Native if self.opts.proposal_shards > 0 => {
                gp::acquire_sharded_profile(
                    &x_obs,
                    &fit,
                    &xc,
                    &params,
                    self.opts.proposal_shards,
                    self.scoring_threads(),
                    &self.opts.shard_exec,
                    // Round counter as the fate salt: the simulated
                    // cluster's fault sequence evolves per propose round
                    // instead of replaying one schedule forever
                    // (wall-clock only — the scored output is
                    // salt-independent).
                    self.rounds as u64,
                    self.opts.kernel_profile,
                )?
            }
            SurrogateBackend::Native => gp::acquire_parallel_profile(
                &x_obs,
                &fit,
                &xc,
                &params,
                self.scoring_threads(),
                self.opts.kernel_profile,
            )?,
            SurrogateBackend::Pjrt => self.surrogate.acquire(&x_obs, &fit, &xc, &params)?,
        };
        Ok(Scored { x_obs, cands, xc, acq: acq_out, params })
    }

    pub fn backend_name(&self) -> &'static str {
        self.surrogate.name()
    }

    /// The cached [`CholeskyState`] matching `params`' kernel key, if any —
    /// introspection for the recovery tests (resume-rebuilt factor must be
    /// bit-identical to the uninterrupted run's).
    pub fn cached_state(&self, params: &GpParams) -> Option<&CholeskyState> {
        self.chol_cache.iter().find(|s| s.matches_params(params))
    }

    /// Restore state after a journal replay: set the adaptive-beta clock to
    /// the journaled `rounds` and warm the incremental Cholesky cache over
    /// the replayed history window, so the first post-resume fit pays the
    /// O(kn²) append path instead of an O(n³) from-scratch refactorization
    /// per kernel key. The warm-up itself is one factorization pass (O(n²)
    /// per replayed row — the same per-observation cost the uninterrupted
    /// run paid), and by the append/scratch equivalence property the
    /// resulting factor is bit-identical to the state the crashed process
    /// held over the same rows. With lengthscale tuning enabled every grid
    /// point is warmed, mirroring `fit_and_score`'s per-grid-point caches.
    pub fn rehydrate(&mut self, history: &History, rounds: usize) -> Result<()> {
        self.rounds = rounds;
        if history.is_empty() {
            return Ok(());
        }
        let x_obs = self.encode_history(history);
        if self.surrogate.consumes_shared_dists() {
            self.update_dist_cache(&x_obs);
        }
        let yn = match self.opts.y_transform {
            YTransform::Normalize => normalize_y(history.values()).0,
            YTransform::RankGauss => acq::rank_gauss(history.values()),
        };
        let d = self.encoder.dims();
        if self.opts.tune_lengthscale {
            for ls in LML_LENGTHSCALE_GRID {
                let mut p = GpParams::new(d).with_lengthscale(ls);
                p.noise = self.opts.noise;
                self.fit_cached(&x_obs, &yn, &p)?;
            }
        } else {
            let mut p = GpParams::new(d);
            p.noise = self.opts.noise;
            self.fit_cached(&x_obs, &yn, &p)?;
        }
        Ok(())
    }

    /// [`rehydrate`](Self::rehydrate) for an async resume with configs
    /// still in flight: warms the cache over the constant-liar augmented
    /// view `[history + pending]` — the exact matrix the first post-resume
    /// liar fit covers (built by the same [`super::liar_augmented`] the
    /// propose path uses), so that fit pays the append path instead of a
    /// from-scratch refactorization. With no pending work this is plain
    /// `rehydrate`.
    pub fn rehydrate_pending(
        &mut self,
        history: &History,
        pending: &[Config],
        rounds: usize,
    ) -> Result<()> {
        if pending.is_empty() {
            return self.rehydrate(history, rounds);
        }
        let augmented = super::liar_augmented(history, pending, self.max_obs());
        self.rehydrate(&augmented, rounds)
    }

    /// Full distance-matrix builds performed so far (test introspection:
    /// the shared-distance grid amortizes to one build per window).
    pub fn dist_matrix_builds(&self) -> usize {
        self.dist_builds
    }

    /// Incremental distance-row appends performed so far.
    pub fn dist_matrix_appends(&self) -> usize {
        self.dist_appends
    }

    /// Tiles evicted by the Fast profile's truncate-and-regrow so far
    /// (always 0 in Exact, which has no tiles).
    pub fn dist_matrix_evicts(&self) -> usize {
        self.dist_evicts
    }

    /// `(builds, appends, evicts)` for [`super::BatchOptimizer::dist_cache_stats`]
    /// — the telemetry triple surfaced in `TuningResult` and the CLI
    /// summary.
    pub fn dist_cache_stats(&self) -> (u64, u64, u64) {
        (self.dist_builds as u64, self.dist_appends as u64, self.dist_evicts as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::svm_space;

    fn history_from(space: &SearchSpace, n: usize, seed: u64) -> History {
        let mut rng = Pcg64::new(seed);
        let mut h = History::new();
        for cfg in space.sample_n(&mut rng, n) {
            let v = -(cfg.get_f64("c").unwrap() - 50.0).abs();
            h.push(cfg, v);
        }
        h
    }

    #[test]
    fn fit_and_score_shapes() {
        let space = svm_space();
        let mut core = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        let h = history_from(&space, 12, 3);
        let mut rng = Pcg64::new(4);
        let s = core.fit_and_score(&h, 1, &mut rng).unwrap();
        assert_eq!(s.x_obs.rows(), 12);
        assert_eq!(s.cands.len(), s.xc.rows());
        assert_eq!(s.acq.ucb.len(), s.cands.len());
        assert_eq!(s.acq.w.rows(), 12);
        // Winner materialization works after the encoded matrix moved out.
        assert_eq!(s.cands.config(0).len(), 2);
    }

    #[test]
    fn rounds_advance_beta() {
        let space = svm_space();
        let mut core = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        let h = history_from(&space, 8, 5);
        let mut rng = Pcg64::new(6);
        let s1 = core.fit_and_score(&h, 1, &mut rng).unwrap();
        let s2 = core.fit_and_score(&h, 1, &mut rng).unwrap();
        assert!(s2.params.beta >= s1.params.beta);
        assert_eq!(core.rounds, 2);
    }

    #[test]
    fn fixed_beta_respected() {
        let space = svm_space();
        let opts = GpOptions { fixed_beta: Some(1.7), ..Default::default() };
        let mut core = BayesianCore::new(space.clone(), opts).unwrap();
        let h = history_from(&space, 8, 5);
        let mut rng = Pcg64::new(6);
        let s = core.fit_and_score(&h, 4, &mut rng).unwrap();
        assert_eq!(s.params.beta, 1.7);
    }

    #[test]
    fn lengthscale_tuning_runs() {
        let space = svm_space();
        let opts = GpOptions { tune_lengthscale: true, ..Default::default() };
        let mut core = BayesianCore::new(space.clone(), opts).unwrap();
        let h = history_from(&space, 15, 8);
        let mut rng = Pcg64::new(9);
        let s = core.fit_and_score(&h, 1, &mut rng).unwrap();
        let ls = 1.0 / s.params.inv_lengthscale[0];
        assert!(LML_LENGTHSCALE_GRID.iter().any(|&v| (ls - v).abs() < 1e-9));
    }

    #[test]
    fn max_obs_answers_from_the_backend() {
        let space = svm_space();
        let native = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        assert_eq!(native.max_obs(), usize::MAX, "native GP is unbounded");
        let opts = GpOptions { backend: SurrogateBackend::Pjrt, ..Default::default() };
        let pjrt = BayesianCore::new(space, opts).unwrap();
        // Must equal whatever the surrogate reports (manifest capacity, or
        // the fallback default when no artifacts are on disk) — not a
        // hardcoded optimizer-side constant.
        assert!(pjrt.max_obs() < usize::MAX, "pjrt artifacts are bounded");
        assert!(pjrt.max_obs() >= 128);
    }

    /// The Cholesky cache must be a pure optimization: a core that reuses
    /// its state across growing-history rounds produces *exactly* the same
    /// scores as a fresh core fitting from scratch (the append path is
    /// bit-identical arithmetic).
    #[test]
    fn chol_cache_matches_fresh_fits_exactly() {
        let space = svm_space();
        let opts = GpOptions { fixed_beta: Some(2.0), ..Default::default() };
        let h1 = history_from(&space, 10, 21);
        let mut h2 = h1.clone();
        for cfg in space.sample_n(&mut Pcg64::new(22), 3) {
            let v = -(cfg.get_f64("c").unwrap() - 50.0).abs();
            h2.push(cfg, v);
        }

        let mut warm = BayesianCore::new(space.clone(), opts.clone()).unwrap();
        warm.fit_and_score(&h1, 1, &mut Pcg64::new(30)).unwrap(); // primes the cache
        let s_warm = warm.fit_and_score(&h2, 1, &mut Pcg64::new(31)).unwrap();

        let mut fresh = BayesianCore::new(space, opts).unwrap();
        let s_fresh = fresh.fit_and_score(&h2, 1, &mut Pcg64::new(31)).unwrap();

        assert_eq!(s_warm.acq.mean, s_fresh.acq.mean);
        assert_eq!(s_warm.acq.var, s_fresh.acq.var);
        assert_eq!(s_warm.acq.ucb, s_fresh.acq.ucb);
    }

    /// Windowing (`truncate_to_recent` / `recent`) breaks the cached
    /// prefix; the refit must be transparent and exact.
    #[test]
    fn window_shrink_invalidates_cache_transparently() {
        let space = svm_space();
        let opts = GpOptions { fixed_beta: Some(2.0), ..Default::default() };
        let h = history_from(&space, 14, 23);
        let shrunk = h.recent(9); // drops the 5 oldest observations

        let mut warm = BayesianCore::new(space.clone(), opts.clone()).unwrap();
        warm.fit_and_score(&h, 1, &mut Pcg64::new(40)).unwrap();
        let s_warm = warm.fit_and_score(&shrunk, 1, &mut Pcg64::new(41)).unwrap();

        let mut fresh = BayesianCore::new(space, opts).unwrap();
        let s_fresh = fresh.fit_and_score(&shrunk, 1, &mut Pcg64::new(41)).unwrap();

        assert_eq!(s_warm.acq.mean, s_fresh.acq.mean);
        assert_eq!(s_warm.acq.var, s_fresh.acq.var);
        assert_eq!(s_warm.acq.ucb, s_fresh.acq.ucb);
    }

    /// One shared squared-distance matrix per round feeds all five LML
    /// grid points, and append-only growth reuses it incrementally — the
    /// grid's kernel-build cost amortizes from 5 O(n²d) builds per round
    /// to 1 per *window*, plus elementwise exp maps.
    #[test]
    fn lml_grid_shares_one_distance_matrix_across_rounds() {
        let space = svm_space();
        let opts =
            GpOptions { tune_lengthscale: true, fixed_beta: Some(2.0), ..Default::default() };
        let mut core = BayesianCore::new(space.clone(), opts).unwrap();
        let h = history_from(&space, 14, 31);
        let prefix = |n: usize| {
            let mut p = History::new();
            for i in 0..n {
                p.push(h.configs()[i].clone(), h.values()[i]);
            }
            p
        };
        let mut rng = Pcg64::new(60);

        // Round 1 over the first 10 rows: one build despite 5 grid fits.
        core.fit_and_score(&prefix(10), 1, &mut rng).unwrap();
        assert_eq!(core.dist_matrix_builds(), 1, "grid must share one distance build");
        assert_eq!(core.dist_matrix_appends(), 0);

        // Round 2, append-only growth to 14 rows: no new build, one append.
        core.fit_and_score(&h, 1, &mut rng).unwrap();
        assert_eq!(core.dist_matrix_builds(), 1, "append-only growth must not rebuild");
        assert_eq!(core.dist_matrix_appends(), 1);

        // Same window again: cache untouched.
        core.fit_and_score(&h, 1, &mut rng).unwrap();
        assert_eq!(core.dist_matrix_builds(), 1);
        assert_eq!(core.dist_matrix_appends(), 1);

        // Window slide (drops the oldest rows): prefix broken, one rebuild.
        core.fit_and_score(&h.recent(9), 1, &mut rng).unwrap();
        assert_eq!(core.dist_matrix_builds(), 2, "window slide pays one rebuild");
    }

    /// The Cholesky cache must be *most-recently-used* ordered: reusing a
    /// key refreshes its recency, and overflow evicts the coldest key —
    /// never a just-touched one. (Regression: the old swap_remove +
    /// remove(0) scheme scrambled the order and could evict the
    /// fixed-default key while grid keys churned.)
    #[test]
    fn chol_cache_eviction_is_true_lru() {
        let space = svm_space();
        let mut core = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        let h = history_from(&space, 8, 41);
        let mut rng = Pcg64::new(70);
        let s = core.fit_and_score(&h, 1, &mut rng).unwrap(); // builds x/dist caches
        let x = s.x_obs.clone();
        let y = vec![0.0; x.rows()];
        let (d, noise) = (x.cols(), core.opts.noise);
        let key = move |ls: f64| {
            let mut p = GpParams::new(d).with_lengthscale(ls);
            p.noise = noise;
            p
        };
        core.chol_cache.clear();
        // Fill the cache to capacity: the "default" key first, then grid-
        // like churn keys (all distinct lengthscales).
        let default_ls = 0.31;
        let churn: Vec<f64> = (0..CHOL_CACHE_MAX - 1).map(|i| 0.4 + 0.07 * i as f64).collect();
        core.fit_cached(&x, &y, &key(default_ls)).unwrap();
        for &ls in &churn {
            core.fit_cached(&x, &y, &key(ls)).unwrap();
        }
        assert_eq!(core.chol_cache.len(), CHOL_CACHE_MAX);
        // A full churn round re-touches every grid key, then the default:
        // recency order must now be [churn..., default].
        for &ls in &churn {
            core.fit_cached(&x, &y, &key(ls)).unwrap();
        }
        core.fit_cached(&x, &y, &key(default_ls)).unwrap();
        assert_eq!(core.chol_cache.len(), CHOL_CACHE_MAX, "touches must not grow the cache");
        assert!(
            core.cached_state(&key(default_ls)).is_some(),
            "default key must survive a full churn round"
        );
        // Overflow with a brand-new key: the true LRU (churn[0]) is
        // evicted; the just-touched default key survives.
        core.fit_cached(&x, &y, &key(0.97)).unwrap();
        assert_eq!(core.chol_cache.len(), CHOL_CACHE_MAX);
        assert!(
            core.cached_state(&key(churn[0])).is_none(),
            "the least-recently-used key must be the one evicted"
        );
        assert!(
            core.cached_state(&key(default_ls)).is_some(),
            "a just-touched key must never be evicted by churn"
        );
        assert!(core.cached_state(&key(0.97)).is_some());
    }

    /// The deterministic-parallel-scoring contract at the optimizer level:
    /// `fit_and_score` output is byte-identical for every
    /// `proposal_threads` setting (including 0 = auto).
    #[test]
    fn fit_and_score_is_byte_identical_across_proposal_threads() {
        let space = svm_space();
        let h = history_from(&space, 12, 51);
        let run = |threads: usize| {
            let opts = GpOptions {
                proposal_threads: threads,
                fixed_beta: Some(2.0),
                mc_samples: 257, // odd: ragged chunk boundaries
                ..Default::default()
            };
            let mut core = BayesianCore::new(space.clone(), opts).unwrap();
            core.fit_and_score(&h, 1, &mut Pcg64::new(80)).unwrap()
        };
        let base = run(1);
        for threads in [2usize, 8, 0] {
            let s = run(threads);
            assert_eq!(s.xc, base.xc, "{threads}: candidate set differs");
            assert_eq!(s.cands.column(0), base.cands.column(0), "{threads}: columns differ");
            assert_eq!(s.acq.ucb, base.acq.ucb, "{threads} threads: ucb deviates");
            assert_eq!(s.acq.mean, base.acq.mean, "{threads} threads: mean deviates");
            assert_eq!(s.acq.var, base.acq.var, "{threads} threads: var deviates");
            assert_eq!(s.acq.w, base.acq.w, "{threads} threads: w deviates");
        }
    }

    /// The sharded-scoring contract at the optimizer level: `fit_and_score`
    /// output is byte-identical across every `proposal_shards` ∈ {0, 1, 3}
    /// × scheduler-kind (serial / threaded / celery-sim with its fault
    /// fates firing) × `proposal_threads` setting. `proposal_shards = 0`
    /// is the local-only path — today's behavior byte-for-byte.
    #[test]
    fn fit_and_score_is_byte_identical_across_proposal_shards_and_schedulers() {
        use crate::gp::ShardExec;
        let space = svm_space();
        let h = history_from(&space, 11, 52);
        let faulty = crate::scheduler::celery::CelerySimConfig {
            workers: 2,
            base_latency_ms: 0.05,
            straggler_prob: 0.3,
            straggler_factor: 1000.0,
            crash_prob: 0.3,
            result_timeout: std::time::Duration::from_millis(2),
        };
        let run = |shards: usize, threads: usize, exec: ShardExec| {
            let opts = GpOptions {
                proposal_shards: shards,
                proposal_threads: threads,
                shard_exec: exec,
                fixed_beta: Some(2.0),
                mc_samples: 193, // odd: ragged shard boundaries
                ..Default::default()
            };
            let mut core = BayesianCore::new(space.clone(), opts).unwrap();
            core.fit_and_score(&h, 1, &mut Pcg64::new(81)).unwrap()
        };
        let base = run(0, 1, ShardExec::Serial);
        for shards in [0usize, 1, 3] {
            for threads in [1usize, 2] {
                for exec in [
                    ShardExec::Serial,
                    ShardExec::Threaded,
                    ShardExec::CelerySim { config: faulty.clone(), seed: 7 },
                ] {
                    let tag = format!("shards={shards} threads={threads} {exec:?}");
                    let s = run(shards, threads, exec);
                    assert_eq!(s.xc, base.xc, "{tag}: candidate set differs");
                    assert_eq!(s.acq.ucb, base.acq.ucb, "{tag}: ucb deviates");
                    assert_eq!(s.acq.mean, base.acq.mean, "{tag}: mean deviates");
                    assert_eq!(s.acq.var, base.acq.var, "{tag}: var deviates");
                    assert_eq!(s.acq.w, base.acq.w, "{tag}: w deviates");
                }
            }
        }
    }

    /// Satellite: `rehydrate_pending` must warm the cache over the exact
    /// constant-liar view the first post-resume fit covers — bit-identical
    /// to the state a live (uninterrupted) core holds after fitting the
    /// same augmented history.
    #[test]
    fn rehydrate_pending_warms_the_liar_fit_state() {
        let space = svm_space();
        let opts = GpOptions { fixed_beta: Some(2.0), ..Default::default() };
        let h = history_from(&space, 9, 61);
        let mut rng = Pcg64::new(90);
        let pending = space.sample_n(&mut rng, 3);

        // The live core's last pre-crash action: a constant-liar fit over
        // [history + pending].
        let augmented = crate::optimizer::liar_augmented(&h, &pending, usize::MAX);
        let mut live = BayesianCore::new(space.clone(), opts.clone()).unwrap();
        live.fit_and_score(&augmented, 1, &mut Pcg64::new(91)).unwrap();

        // The resumed core warms through rehydrate_pending.
        let mut resumed = BayesianCore::new(space.clone(), opts).unwrap();
        resumed.rehydrate_pending(&h, &pending, 1).unwrap();
        assert_eq!(resumed.rounds, 1);

        let d = Encoder::new(&space).dims();
        let mut params = GpParams::new(d);
        params.noise = GpOptions::default().noise;
        let live_state = live.cached_state(&params).expect("live liar-fit state");
        let warm_state = resumed.cached_state(&params).expect("rehydrated liar state");
        assert_eq!(
            warm_state.rows(),
            h.len() + pending.len(),
            "warm state must cover history + pending, not history alone"
        );
        assert_eq!(
            warm_state.factor(),
            live_state.factor(),
            "warmed factor must be bit-identical to the live liar fit's"
        );
    }

    /// The tiled triangle against the scalar D² oracle, plus its two
    /// structural contracts: tile-granular growth is bit-identical to a
    /// from-scratch build over the same rows, and f32 slabs hold ≤ ~55%
    /// of the dense f64 footprint while staying within f32 precision.
    #[test]
    fn tiled_dist_cache_matches_oracle_and_grows_bitwise() {
        use crate::linalg::{dot, dot_fast};
        let (n, d) = (192, 5); // 3 full 64-row blocks
        let mut rng = Pcg64::new(13);
        let x = Matrix::from_fn(n, d, |_, _| rng.next_f64() * 3.0 - 1.0);
        let norms: Vec<f64> = (0..n).map(|r| dot_fast(x.row(r), x.row(r))).collect();
        let mut full = TiledDistCache::new(TileElem::F64);
        assert_eq!(full.sync(&x, &norms, 0), 0, "fresh build evicts nothing");
        assert_eq!(full.rows(), n);
        assert_eq!(full.tile_count(), 6); // blocks of 1 + 2 + 3 tiles
        // Every entry within 1e-10 relative of the scalar-dot oracle.
        for i in (0..n).step_by(7) {
            for j in (0..n).step_by(5) {
                let want = kernel::sq_dist_from_parts(
                    dot(x.row(i), x.row(i)),
                    dot(x.row(j), x.row(j)),
                    dot(x.row(i), x.row(j)),
                );
                let got = full.get(i, j);
                assert!(
                    (got - want).abs() <= 1e-10 * want.abs().max(1.0),
                    "d2[{i},{j}]: tiled {got} vs oracle {want}"
                );
            }
        }
        // Grow from a 130-row prefix: block 0+1 (rows 0..128) survive
        // bitwise, block 2 (2 rows + 1 partial-tile row block) is evicted
        // and regrown; the result is bit-identical to the fresh build.
        let sub = Matrix::from_fn(130, d, |i, j| x[(i, j)]);
        let mut grown = TiledDistCache::new(TileElem::F64);
        grown.sync(&sub, &norms[..130], 0);
        assert_eq!(grown.rows(), 130);
        let evicted = grown.sync(&x, &norms, 130);
        assert_eq!(evicted, 3, "row block 2 holds tiles (2,0..=2)");
        assert_eq!(grown.to_dense(), full.to_dense(), "growth must be bit-identical");
        // f32 slabs: ≤ ~55% of the dense footprint (here exactly 25%:
        // half for the triangle, half again for f32), f32-accurate.
        let mut half = TiledDistCache::new(TileElem::F32);
        half.sync(&x, &norms, 0);
        let dense_bytes = n * n * 8;
        assert_eq!(half.footprint_bytes(), 6 * DIST_TILE * DIST_TILE * 4);
        assert!(
            (half.footprint_bytes() as f64) <= 0.55 * dense_bytes as f64,
            "f32 tiles must cut the dense footprint to ≤ ~55%"
        );
        // f64 tiles halve the footprint only asymptotically (tile-padding
        // overhead shrinks as nblocks grows); here 3 blocks give 2/3.
        assert_eq!(full.footprint_bytes(), 6 * DIST_TILE * DIST_TILE * 8);
        for i in (0..n).step_by(11) {
            for j in (0..n).step_by(13) {
                let (a, b) = (full.get(i, j), half.get(i, j));
                assert!(
                    (a - b).abs() <= 1e-6 * a.abs().max(1.0),
                    "d2[{i},{j}]: f32 {b} too far from f64 {a}"
                );
            }
        }
    }

    /// End-to-end Fast profile at the optimizer level: within tolerance of
    /// Exact, byte-identical across `proposal_threads` ×
    /// `proposal_shards`, run-to-run deterministic, and the tiled cache
    /// follows the build/append/evict state machine (counters observable
    /// through `dist_cache_stats`).
    #[test]
    fn fast_profile_fit_and_score_is_deterministic_and_near_exact() {
        use crate::gp::{KernelProfile, ShardExec};
        let space = svm_space();
        let h = history_from(&space, 13, 57);
        let run = |profile: KernelProfile, threads: usize, shards: usize| {
            let opts = GpOptions {
                kernel_profile: profile,
                proposal_threads: threads,
                proposal_shards: shards,
                shard_exec: if shards > 0 { ShardExec::Threaded } else { ShardExec::Serial },
                fixed_beta: Some(2.0),
                mc_samples: 257, // odd: ragged chunk/lane boundaries
                ..Default::default()
            };
            let mut core = BayesianCore::new(space.clone(), opts).unwrap();
            core.fit_and_score(&h, 1, &mut Pcg64::new(83)).unwrap()
        };
        let exact = run(KernelProfile::Exact, 1, 0);
        let fast = run(KernelProfile::Fast, 1, 0);
        assert_eq!(fast.xc, exact.xc, "candidate generation is profile-independent");
        // Tolerance-equal to Exact end to end. The kernel-level contract
        // is 1e-10; one Cholesky solve over the perturbed Gram can
        // amplify by the (noise-jittered) condition number, so the
        // end-to-end bound is 1e-8 relative.
        for c in 0..fast.acq.ucb.len() {
            for (name, a, b) in [
                ("ucb", exact.acq.ucb[c], fast.acq.ucb[c]),
                ("mean", exact.acq.mean[c], fast.acq.mean[c]),
                ("var", exact.acq.var[c], fast.acq.var[c]),
            ] {
                assert!(
                    (a - b).abs() <= 1e-8 * a.abs().max(1.0),
                    "{name}[{c}]: exact {a} vs fast {b}"
                );
            }
        }
        // Run-to-run determinism and threads×shards byte-invariance.
        for (threads, shards) in [(1, 0), (2, 0), (8, 0), (1, 1), (2, 3)] {
            let s = run(KernelProfile::Fast, threads, shards);
            let tag = format!("threads={threads} shards={shards}");
            assert_eq!(s.acq.ucb, fast.acq.ucb, "{tag}: fast ucb deviates");
            assert_eq!(s.acq.mean, fast.acq.mean, "{tag}: fast mean deviates");
            assert_eq!(s.acq.var, fast.acq.var, "{tag}: fast var deviates");
            assert_eq!(s.acq.w, fast.acq.w, "{tag}: fast w deviates");
        }
    }

    /// The Fast profile's cache lifecycle through `fit_and_score`: the LML
    /// grid shares one tiled build, append-only growth appends, and a
    /// window slide rebuilds — evicting the old triangle's tiles into the
    /// `dist_cache_stats` evict counter.
    #[test]
    fn fast_profile_tiled_cache_counts_builds_appends_and_evicts() {
        use crate::gp::KernelProfile;
        let space = svm_space();
        let opts = GpOptions {
            kernel_profile: KernelProfile::Fast,
            tune_lengthscale: true,
            fixed_beta: Some(2.0),
            ..Default::default()
        };
        let mut core = BayesianCore::new(space.clone(), opts).unwrap();
        let h = history_from(&space, 14, 31);
        let prefix = |n: usize| {
            let mut p = History::new();
            for i in 0..n {
                p.push(h.configs()[i].clone(), h.values()[i]);
            }
            p
        };
        let mut rng = Pcg64::new(61);
        core.fit_and_score(&prefix(10), 1, &mut rng).unwrap();
        assert_eq!(core.dist_cache_stats(), (1, 0, 0), "grid shares one tiled build");
        // Growth 10 → 14 rows: one append; both windows live inside one
        // partial 64-row block, so the append evicts that 1 tile and
        // regrows it (sub-tile granularity always rebuilds the partial
        // block — row blocks only survive appends once fully covered).
        core.fit_and_score(&h, 1, &mut rng).unwrap();
        assert_eq!(core.dist_cache_stats(), (1, 1, 1), "growth appends, regrowing the tile");
        core.fit_and_score(&h, 1, &mut rng).unwrap();
        assert_eq!(core.dist_cache_stats(), (1, 1, 1), "same window: cache untouched");
        // Window slide: prefix broken → rebuild, old triangle evicted
        // (14 rows < one 64-row block → exactly 1 more tile).
        core.fit_and_score(&h.recent(9), 1, &mut rng).unwrap();
        assert_eq!(core.dist_cache_stats(), (2, 1, 2), "slide rebuilds and evicts");
    }

    #[test]
    fn grid_search_keeps_one_state_per_lengthscale() {
        let space = svm_space();
        let opts =
            GpOptions { tune_lengthscale: true, fixed_beta: Some(2.0), ..Default::default() };
        let mut core = BayesianCore::new(space.clone(), opts).unwrap();
        let h = history_from(&space, 10, 25);
        core.fit_and_score(&h, 1, &mut Pcg64::new(50)).unwrap();
        assert_eq!(
            core.chol_cache.len(),
            LML_LENGTHSCALE_GRID.len(),
            "one cached state per grid point"
        );
        // A second round reuses all five without growing the cache.
        core.fit_and_score(&h, 1, &mut Pcg64::new(51)).unwrap();
        assert_eq!(core.chol_cache.len(), LML_LENGTHSCALE_GRID.len());
        assert!(core.chol_cache.iter().all(|s| s.rows() == 10));
    }
}
