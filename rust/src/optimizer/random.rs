//! Random search baseline (paper §2.3: "Mango also supports a random
//! optimizer which selects a batch of random configurations").

use super::{BatchOptimizer, History};
use crate::space::{Config, SearchSpace};
use crate::util::rng::Pcg64;
use anyhow::Result;

pub struct RandomOptimizer {
    space: SearchSpace,
}

impl RandomOptimizer {
    pub fn new(space: SearchSpace) -> Self {
        Self { space }
    }
}

impl BatchOptimizer for RandomOptimizer {
    fn propose(
        &mut self,
        _history: &History,
        batch_size: usize,
        rng: &mut Pcg64,
    ) -> Result<Vec<Config>> {
        Ok(self.space.sample_n(rng, batch_size))
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::xgboost_space;

    #[test]
    fn proposes_requested_batch() {
        let mut opt = RandomOptimizer::new(xgboost_space());
        let mut rng = Pcg64::new(1);
        let batch = opt.propose(&History::new(), 5, &mut rng).unwrap();
        assert_eq!(batch.len(), 5);
        // batches differ across calls
        let batch2 = opt.propose(&History::new(), 5, &mut rng).unwrap();
        assert_ne!(batch, batch2);
    }
}
