//! Clustering batch selection (Groves & Pyzer-Knapp 2018) — the paper's
//! second parallel algorithm: "create clusters of acquisition function in
//! spatially distinct search spaces and select the maximum value within
//! each cluster".

use super::bayesian::BayesianCore;
use super::kmeans::kmeans;
use super::{BatchOptimizer, History};
use crate::linalg::Matrix;
use crate::space::Config;
use crate::util::rng::Pcg64;
use crate::util::stats::nan_as_worst;
use anyhow::Result;

pub struct ClusteringOptimizer {
    core: BayesianCore,
    /// Fraction of top-UCB candidates clustered (the paper clusters the
    /// high-acquisition region, not the whole MC sample).
    pub top_fraction: f64,
}

impl ClusteringOptimizer {
    pub fn new(core: BayesianCore) -> Self {
        Self { core, top_fraction: 0.2 }
    }
}

impl BatchOptimizer for ClusteringOptimizer {
    fn propose(
        &mut self,
        history: &History,
        batch_size: usize,
        rng: &mut Pcg64,
    ) -> Result<Vec<Config>> {
        if history.len() < self.core.opts.initial_random.max(2) {
            return Ok(self.core.space.sample_columnar(rng, batch_size).into_configs());
        }
        let scored = self.core.fit_and_score(history, batch_size, rng)?;
        let m = scored.cands.len();

        // Rank candidates by UCB, keep the top slice (>= 4 per cluster).
        let mut order: Vec<usize> = (0..m).collect();
        // A NaN UCB (e.g. from a hand-edited history dump) must sort as
        // the worst candidate, not panic the run or outrank +inf.
        order.sort_by(|&a, &b| {
            nan_as_worst(scored.acq.ucb[b]).total_cmp(&nan_as_worst(scored.acq.ucb[a]))
        });
        let keep = ((m as f64 * self.top_fraction) as usize)
            .max(batch_size * 4)
            .min(m);
        let top = &order[..keep];

        // Cluster the top region in encoded space.
        let d = scored.xc.cols();
        let rows = Matrix::from_fn(keep, d, |i, j| scored.xc[(top[i], j)]);
        let km = kmeans(&rows, batch_size, rng, 25);

        // Max-UCB member per cluster (order[] is UCB-descending, so the
        // first member seen per cluster is its maximum). Only the winners
        // are materialized into Configs.
        let mut batch: Vec<Config> = Vec::with_capacity(batch_size);
        let mut cluster_done = vec![false; km.k];
        for (pos, &cand) in top.iter().enumerate() {
            let c = km.assignment[pos];
            if !cluster_done[c] {
                cluster_done[c] = true;
                batch.push(scored.cands.config(cand));
                if batch.len() == batch_size {
                    break;
                }
            }
        }
        // Degenerate cases (fewer clusters than k): pad with next-best UCB.
        for &cand in top.iter() {
            if batch.len() >= batch_size {
                break;
            }
            let cfg = scored.cands.config(cand);
            if !batch.contains(&cfg) {
                batch.push(cfg);
            }
        }
        while batch.len() < batch_size {
            batch.push(self.core.space.sample(rng));
        }
        Ok(batch)
    }

    fn surrogate_capacity(&self) -> usize {
        self.core.max_obs()
    }

    fn rounds(&self) -> usize {
        self.core.rounds
    }

    fn rehydrate(&mut self, history: &History, rounds: usize) -> Result<()> {
        self.core.rehydrate(history, rounds)
    }

    fn rehydrate_pending(
        &mut self,
        history: &History,
        pending: &[Config],
        rounds: usize,
    ) -> Result<()> {
        self.core.rehydrate_pending(history, pending, rounds)
    }

    fn dist_cache_stats(&self) -> (u64, u64, u64) {
        self.core.dist_cache_stats()
    }

    fn name(&self) -> &'static str {
        "clustering"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::GpOptions;
    use crate::space::svm_space;

    fn seeded_history(n: usize) -> History {
        let space = svm_space();
        let mut rng = Pcg64::new(3);
        let mut h = History::new();
        for cfg in space.sample_n(&mut rng, n) {
            let c = cfg.get_f64("c").unwrap();
            h.push(cfg, -(c - 30.0).abs());
        }
        h
    }

    #[test]
    fn proposes_distinct_spatially_spread_batch() {
        let space = svm_space();
        let core = BayesianCore::new(space, GpOptions::default()).unwrap();
        let mut opt = ClusteringOptimizer::new(core);
        let mut rng = Pcg64::new(11);
        let h = seeded_history(10);
        let batch = opt.propose(&h, 5, &mut rng).unwrap();
        assert_eq!(batch.len(), 5);
        for i in 0..5 {
            for j in (i + 1)..5 {
                assert_ne!(batch[i], batch[j]);
            }
        }
    }

    #[test]
    fn batch_one_picks_ucb_argmax_region() {
        // With k=1 and a small exploitation-leaning beta, the clustering
        // strategy degenerates to plain UCB argmax: the proposal must be
        // near the incumbent optimum once the GP has seen enough data.
        let space = svm_space();
        let opts = GpOptions { fixed_beta: Some(1.0), ..Default::default() };
        let core = BayesianCore::new(space, opts).unwrap();
        let mut opt = ClusteringOptimizer::new(core);
        let mut rng = Pcg64::new(13);
        let h = seeded_history(40);
        let batch = opt.propose(&h, 1, &mut rng).unwrap();
        let c = batch[0].get_f64("c").unwrap();
        assert!((c - 30.0).abs() < 25.0, "proposal c = {c} too far from optimum 30");
    }

    #[test]
    fn nan_history_value_does_not_panic() {
        // A NaN objective can only reach the optimizer through a
        // hand-edited history dump (the tuner rejects non-finite results);
        // the UCB ranking sort must survive it.
        let space = svm_space();
        let core = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        let mut opt = ClusteringOptimizer::new(core);
        let mut rng = Pcg64::new(29);
        let mut h = seeded_history(9);
        h.push(space.sample(&mut rng), f64::NAN);
        let batch = opt.propose(&h, 3, &mut rng).unwrap();
        assert_eq!(batch.len(), 3);
    }

    #[test]
    fn cold_start_random() {
        let space = svm_space();
        let core = BayesianCore::new(space, GpOptions::default()).unwrap();
        let mut opt = ClusteringOptimizer::new(core);
        let mut rng = Pcg64::new(17);
        let batch = opt.propose(&History::new(), 4, &mut rng).unwrap();
        assert_eq!(batch.len(), 4);
    }
}
