//! Batch Thompson sampling — the paper's conclusion names "more parallel
//! optimization algorithms" as future work; this is the canonical next one
//! (Kandasamy et al. 2018: parallelised Thompson sampling).
//!
//! Over a discrete Monte-Carlo candidate set, each batch slot draws an
//! independent approximate posterior sample (mean + σ·z per candidate,
//! marginal approximation — exact joint sampling needs the m×m candidate
//! covariance) and takes its argmax. Distinct draws decorrelate the batch
//! naturally: no hallucination bookkeeping, no clustering pass.

use super::bayesian::BayesianCore;
use super::{BatchOptimizer, History};
use crate::space::Config;
use crate::util::rng::Pcg64;
use anyhow::Result;

pub struct ThompsonOptimizer {
    core: BayesianCore,
}

impl ThompsonOptimizer {
    pub fn new(core: BayesianCore) -> Self {
        Self { core }
    }
}

impl BatchOptimizer for ThompsonOptimizer {
    fn propose(
        &mut self,
        history: &History,
        batch_size: usize,
        rng: &mut Pcg64,
    ) -> Result<Vec<Config>> {
        if history.len() < self.core.opts.initial_random.max(2) {
            // Cold start goes through the one shared sampling path (the
            // columnar sampler; bit-identical to the legacy sample_n
            // stream) — every batch here materializes anyway.
            return Ok(self.core.space.sample_columnar(rng, batch_size).into_configs());
        }
        let scored = self.core.fit_and_score(history, batch_size, rng)?;
        let m = scored.cands.len();
        let sigmas: Vec<f64> = scored.acq.var.iter().map(|v| v.sqrt()).collect();

        let mut batch: Vec<Config> = Vec::with_capacity(batch_size);
        let mut taken = vec![false; m];
        for _slot in 0..batch_size {
            // One posterior sample per slot; argmax over untaken candidates.
            let mut best: Option<(f64, usize)> = None;
            for c in 0..m {
                if taken[c] {
                    continue;
                }
                let draw = scored.acq.mean[c] + sigmas[c] * rng.normal();
                if best.map_or(true, |(b, _)| draw > b) {
                    best = Some((draw, c));
                }
            }
            match best {
                Some((_, c)) => {
                    taken[c] = true;
                    // Only the per-slot winners materialize into Configs.
                    batch.push(scored.cands.config(c));
                }
                None => break,
            }
        }
        while batch.len() < batch_size {
            batch.push(self.core.space.sample(rng));
        }
        Ok(batch)
    }

    fn surrogate_capacity(&self) -> usize {
        self.core.max_obs()
    }

    fn rounds(&self) -> usize {
        self.core.rounds
    }

    fn rehydrate(&mut self, history: &History, rounds: usize) -> Result<()> {
        self.core.rehydrate(history, rounds)
    }

    fn rehydrate_pending(
        &mut self,
        history: &History,
        pending: &[Config],
        rounds: usize,
    ) -> Result<()> {
        self.core.rehydrate_pending(history, pending, rounds)
    }

    fn dist_cache_stats(&self) -> (u64, u64, u64) {
        self.core.dist_cache_stats()
    }

    fn name(&self) -> &'static str {
        "thompson"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::GpOptions;
    use crate::space::svm_space;

    fn seeded_history(n: usize) -> History {
        let space = svm_space();
        let mut rng = Pcg64::new(23);
        let mut h = History::new();
        for cfg in space.sample_n(&mut rng, n) {
            let c = cfg.get_f64("c").unwrap();
            h.push(cfg, -(c - 45.0).abs());
        }
        h
    }

    #[test]
    fn batch_is_distinct_and_full() {
        let space = svm_space();
        let core = BayesianCore::new(space, GpOptions::default()).unwrap();
        let mut opt = ThompsonOptimizer::new(core);
        let mut rng = Pcg64::new(31);
        let batch = opt.propose(&seeded_history(15), 6, &mut rng).unwrap();
        assert_eq!(batch.len(), 6);
        for i in 0..6 {
            for j in (i + 1)..6 {
                assert_ne!(batch[i], batch[j]);
            }
        }
    }

    #[test]
    fn converges_on_1d_target() {
        let space = svm_space();
        let core = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        let mut opt = ThompsonOptimizer::new(core);
        let mut rng = Pcg64::new(37);
        let mut h = History::new();
        for _ in 0..20 {
            for cfg in opt.propose(&h, 2, &mut rng).unwrap() {
                let c = cfg.get_f64("c").unwrap();
                h.push(cfg, -(c - 45.0).abs());
            }
        }
        let best = h.best().unwrap().1;
        assert!(best > -8.0, "thompson best {best}");
    }

    #[test]
    fn propose_pending_avoids_in_flight_draws() {
        // The constant-liar default also covers the stochastic optimizer:
        // pending points enter the surrogate as observations, and exact
        // duplicates are filtered from the returned batch.
        use crate::optimizer::BatchOptimizer;
        let space = svm_space();
        let core = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        let mut opt = ThompsonOptimizer::new(core);
        let mut rng = Pcg64::new(53);
        let h = seeded_history(12);
        let pending = opt.propose(&h, 3, &mut rng).unwrap();
        for _ in 0..4 {
            let batch = opt.propose_pending(&h, &pending, 2, &mut rng).unwrap();
            for cfg in &batch {
                assert!(!pending.contains(cfg), "re-proposed in-flight {cfg}");
            }
        }
    }

    #[test]
    fn draws_differ_across_slots() {
        // Stochastic acquisition: two consecutive batch-1 proposals on the
        // same history should usually differ (unlike greedy UCB argmax).
        let space = svm_space();
        let core = BayesianCore::new(space, GpOptions::default()).unwrap();
        let mut opt = ThompsonOptimizer::new(core);
        let mut rng = Pcg64::new(41);
        let h = seeded_history(12);
        let proposals: Vec<_> = (0..6)
            .map(|_| opt.propose(&h, 1, &mut rng).unwrap().remove(0))
            .collect();
        let distinct = proposals
            .iter()
            .enumerate()
            .filter(|(i, p)| proposals[..*i].iter().all(|q| &q != p))
            .count();
        assert!(distinct >= 3, "posterior draws should vary, got {distinct}/6 distinct");
    }
}
