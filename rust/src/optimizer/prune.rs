//! Trial-level early stopping: pruners over intermediate-metric reports.
//!
//! An evaluation is no longer atomic — workers stream `report(step, value)`
//! observations mid-flight (Tune's trial schedulers, Sherpa's robust-HPO
//! design), and a [`Pruner`] decides after each report whether the trial is
//! hopeless and should be cancelled. Two classic rules are implemented:
//!
//! * [`MedianRule`] — prune a trial whose latest value is strictly below
//!   the median of the other trials' values at a comparable step;
//! * [`AsyncSuccessiveHalving`] — ASHA: rung milestones at
//!   `r0 * eta^k` steps, keeping the top `floor(n / eta)` of the trials
//!   that reached each rung.
//!
//! **Determinism contract.** A pruner is a *pure function* of the
//! [`ReportBook`] — the journaled report history — and nothing else: no
//! wall clock, no entropy, no iteration-order-dependent state (the book is
//! `BTreeMap`-backed, comparisons use `total_cmp`). The same book always
//! yields the same decision, which is what makes pruning decisions
//! byte-identical run-to-run, identical across schedulers when the report
//! streams are identical, and exactly replayable from the journal on
//! resume (`persist/recover.rs` rebuilds the book; the resumed process
//! re-derives the crashed process's rung state instead of trusting it).
//!
//! Values in the book are in *internal* (maximization) convention, exactly
//! like [`super::History`] — the coordinator negates user values for
//! minimization problems before they reach the book, and NaN reports are
//! folded to `-inf` via [`crate::util::stats::nan_as_worst`] so they can
//! never poison a median or a rung rank.

use crate::util::stats;
use std::collections::BTreeMap;

/// Which pruner a run uses (`--pruner {none,median,asha}`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrunerKind {
    /// No trial-level pruning — byte-identical to the pre-pruning path.
    None,
    /// [`MedianRule`].
    Median,
    /// [`AsyncSuccessiveHalving`].
    Asha,
}

impl PrunerKind {
    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "none" => Some(Self::None),
            "median" => Some(Self::Median),
            "asha" => Some(Self::Asha),
            _ => None,
        }
    }

    /// Inverse of [`from_str`](Self::from_str) (config round trips).
    pub fn as_str(self) -> &'static str {
        match self {
            Self::None => "none",
            Self::Median => "median",
            Self::Asha => "asha",
        }
    }
}

/// The journaled report history: per-proposal streams of
/// `(step, internal_value)` observations, in arrival order.
///
/// Streams of concluded trials stay in the book — the median rule and
/// ASHA both compare a live trial against *everything* that ever reported
/// at a comparable step, finished trials included.
#[derive(Clone, Debug, Default)]
pub struct ReportBook {
    streams: BTreeMap<u64, Vec<(u64, f64)>>,
}

impl ReportBook {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append one report to proposal `pid`'s stream. The trial's "latest"
    /// report is the last pushed, whatever its step label.
    pub fn push(&mut self, pid: u64, step: u64, value: f64) {
        self.streams.entry(pid).or_default().push((step, value));
    }

    /// Proposal `pid`'s reports in arrival order (empty if it never
    /// reported).
    pub fn reports(&self, pid: u64) -> &[(u64, f64)] {
        self.streams.get(&pid).map_or(&[], |v| v.as_slice())
    }

    /// Every proposal that has reported, in ascending pid order.
    pub fn pids(&self) -> impl Iterator<Item = u64> + '_ {
        self.streams.keys().copied()
    }

    /// Drop proposal `pid`'s stream — a fresh submission restarts the
    /// trial from step 0, so its pre-restart reports must not double-count
    /// (the replay applies the same rule at every `async_submit`).
    pub fn reset(&mut self, pid: u64) {
        self.streams.remove(&pid);
    }

    /// Total reports across all streams.
    pub fn len(&self) -> usize {
        self.streams.values().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.streams.is_empty()
    }

    /// A copy of the book restricted to `pid`'s own stream plus the
    /// streams of every other proposal `keep` admits — the stable-replay
    /// visibility cut: a trial's pruning decisions may only see peers
    /// whose membership is a pure function of the journaled fold order,
    /// never of wall-clock arrival timing. Pure and allocation-bounded;
    /// the same `(book, pid, keep)` always yields the same view.
    pub fn filtered(&self, pid: u64, keep: impl Fn(u64) -> bool) -> ReportBook {
        ReportBook {
            streams: self
                .streams
                .iter()
                .filter(|(p, _)| **p == pid || keep(**p))
                .map(|(p, v)| (*p, v.clone()))
                .collect(),
        }
    }
}

/// A trial-level early-stopping rule: a pure function of the report book.
///
/// `should_prune(pid, book)` is consulted immediately after `pid`'s latest
/// report was pushed into `book`; `true` cancels the trial. Implementations
/// must not hold mutable state that the book cannot reconstruct — resume
/// re-derives every decision by replaying the journaled reports through
/// the same rule.
pub trait Pruner: Send + Sync {
    fn should_prune(&self, pid: u64, book: &ReportBook) -> bool;
    fn name(&self) -> &'static str;
}

/// Median-rule pruning: at the trial's latest report `(s, v)`, compare `v`
/// against the median of every *other* trial's last report at a step
/// `<= s`. Prune iff `v` is strictly below that median — ties survive, so
/// lowering a value can only flip a decision toward pruning, never away
/// from it (the monotonicity property `rust/tests/pruning.rs` checks).
///
/// `warmup` is the number of reports a trial must have produced before the
/// rule engages, and at least two other trials must offer a comparable
/// report — with fewer, there is no meaningful median and the trial runs.
#[derive(Clone, Copy, Debug)]
pub struct MedianRule {
    pub warmup: usize,
}

impl Pruner for MedianRule {
    fn should_prune(&self, pid: u64, book: &ReportBook) -> bool {
        let mine = book.reports(pid);
        let Some(&(step, value)) = mine.last() else { return false };
        if mine.len() < self.warmup.max(1) {
            return false;
        }
        let mut others: Vec<f64> = Vec::new();
        for other in book.pids() {
            if other == pid {
                continue;
            }
            // The other trial's most recent report at a comparable step.
            if let Some(&(_, v)) = book
                .reports(other)
                .iter()
                .filter(|(s, _)| *s <= step)
                .last()
            {
                others.push(v);
            }
        }
        if others.len() < 2 {
            return false;
        }
        value < stats::median(&others)
    }

    fn name(&self) -> &'static str {
        "median"
    }
}

/// Asynchronous Successive Halving (ASHA): rung `k` sits at step milestone
/// `r0 * eta^k`. A trial *reaches* rung `k` at its first report with
/// `step >= milestone(k)`, and that report's value is its rung value. At
/// the trial's latest report, only the highest reached milestone is
/// judged: of the `n` trials that reached it, the top
/// `max(1, floor(n / eta))` by rung value survive; a trial survives iff
/// strictly fewer than that many rung values beat its own (ties promote).
#[derive(Clone, Copy, Debug)]
pub struct AsyncSuccessiveHalving {
    /// Rung-0 step milestone (>= 1).
    pub r0: u64,
    /// Reduction factor eta (> 1).
    pub eta: f64,
}

impl AsyncSuccessiveHalving {
    /// Step milestone of rung `k` (exactly `r0 * eta^k` in f64 — `eta` is
    /// validated finite and > 1, so milestones strictly increase).
    fn milestone(&self, k: i32) -> f64 {
        (self.r0.max(1) as f64) * self.eta.powi(k)
    }

    /// Highest rung whose milestone is `<= step`, if any.
    fn rung_of(&self, step: u64) -> Option<i32> {
        let s = step as f64;
        if s < self.milestone(0) {
            return None;
        }
        let mut k = 0i32;
        while self.milestone(k + 1) <= s {
            k += 1;
        }
        Some(k)
    }

    /// The value `pid` carried when it first reached rung `k`.
    fn rung_value(&self, book: &ReportBook, pid: u64, k: i32) -> Option<f64> {
        let m = self.milestone(k);
        book.reports(pid).iter().find(|(s, _)| (*s as f64) >= m).map(|&(_, v)| v)
    }
}

impl Pruner for AsyncSuccessiveHalving {
    fn should_prune(&self, pid: u64, book: &ReportBook) -> bool {
        let Some(&(step, _)) = book.reports(pid).last() else { return false };
        let Some(k) = self.rung_of(step) else { return false };
        let Some(mine) = self.rung_value(book, pid, k) else { return false };
        let rung: Vec<f64> =
            book.pids().filter_map(|p| self.rung_value(book, p, k)).collect();
        let keep = (((rung.len() as f64) / self.eta).floor() as usize).max(1);
        let rank = rung
            .iter()
            .filter(|v| v.total_cmp(&mine) == std::cmp::Ordering::Greater)
            .count();
        rank >= keep
    }

    fn name(&self) -> &'static str {
        "asha"
    }
}

/// Build the configured pruner (`None` for [`PrunerKind::None`]).
pub fn build_pruner(kind: PrunerKind, warmup: usize, reduction: f64) -> Option<Box<dyn Pruner>> {
    match kind {
        PrunerKind::None => None,
        PrunerKind::Median => Some(Box::new(MedianRule { warmup })),
        PrunerKind::Asha => Some(Box::new(AsyncSuccessiveHalving {
            r0: (warmup.max(1)) as u64,
            eta: reduction,
        })),
    }
}

/// Censored-value policy `worst-seen` for pruned trials: the value a
/// pruned trial contributes to the surrogate history is the worse of its
/// last reported value and the worst value already in the history — so a
/// trial cancelled mid-flight can never look *better* than anything that
/// ran to completion. All arguments and the result are in internal
/// (maximization) convention.
///
/// NaN last-reports fold to `-inf` ([`stats::nan_as_worst`]); if the
/// candidate is non-finite (NaN/`-inf` report with no finite history
/// floor) the trial contributes nothing (`None`) — the coordinator then
/// records the pruning without a history entry, exactly like a `Failed`
/// completion. The live event loop and the journal replay both call this
/// one function, so a resumed run's censored values are bit-identical to
/// the crashed process's.
pub fn censored_value(last_internal: f64, worst_history: Option<f64>) -> Option<f64> {
    let last = stats::nan_as_worst(last_internal);
    let candidate = match worst_history {
        Some(w) => last.min(w),
        None => last,
    };
    if candidate.is_finite() {
        Some(candidate)
    } else {
        worst_history.filter(|w| w.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn book(streams: &[(u64, &[(u64, f64)])]) -> ReportBook {
        let mut b = ReportBook::new();
        for (pid, reports) in streams {
            for (s, v) in *reports {
                b.push(*pid, *s, *v);
            }
        }
        b
    }

    #[test]
    fn pruner_kind_round_trips() {
        for kind in [PrunerKind::None, PrunerKind::Median, PrunerKind::Asha] {
            assert_eq!(PrunerKind::from_str(kind.as_str()), Some(kind));
        }
        assert_eq!(PrunerKind::from_str("hyperband"), None);
    }

    #[test]
    fn book_push_reset_and_len() {
        let mut b = ReportBook::new();
        assert!(b.is_empty());
        b.push(3, 1, 0.5);
        b.push(3, 2, 0.6);
        b.push(1, 1, 0.1);
        assert_eq!(b.len(), 3);
        assert_eq!(b.reports(3), &[(1, 0.5), (2, 0.6)]);
        assert_eq!(b.pids().collect::<Vec<_>>(), vec![1, 3]);
        b.reset(3);
        assert_eq!(b.reports(3), &[] as &[(u64, f64)]);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn filtered_view_keeps_own_stream_and_admitted_peers() {
        let b = book(&[(0, &[(1, 0.5)]), (1, &[(1, 1.0)]), (2, &[(1, 2.0)]), (3, &[(1, 3.0)])]);
        let v = b.filtered(2, |p| p < 2);
        assert_eq!(v.pids().collect::<Vec<_>>(), vec![0, 1, 2], "own stream always survives");
        assert_eq!(v.reports(2), &[(1, 2.0)]);
        assert_eq!(v.reports(3), &[] as &[(u64, f64)]);
        assert_eq!(b.pids().count(), 4, "the source book is untouched");
        // The visibility cut can flip a decision: pid 0 (value 0.5) is
        // below the full-book median of {1.0, 2.0, 3.0}, but a cut that
        // admits only pid 3 leaves fewer than two peers — no median, no
        // pruning.
        let p = MedianRule { warmup: 1 };
        assert!(p.should_prune(0, &b));
        let narrow = b.filtered(0, |p| p == 3);
        assert_eq!(narrow.pids().collect::<Vec<_>>(), vec![0, 3]);
        assert!(!p.should_prune(0, &narrow), "one peer is below the two-other floor");
    }

    #[test]
    fn median_rule_needs_warmup_and_two_others() {
        let p = MedianRule { warmup: 2 };
        // Only one report: below warmup.
        let b = book(&[(0, &[(1, -9.0)]), (1, &[(1, 1.0)]), (2, &[(1, 2.0)])]);
        assert!(!p.should_prune(0, &b));
        // Two reports but only one other trial: no median.
        let b = book(&[(0, &[(1, -9.0), (2, -9.0)]), (1, &[(1, 1.0)])]);
        assert!(!p.should_prune(0, &b));
        // Two others at comparable steps: now it prunes.
        let b = book(&[
            (0, &[(1, -9.0), (2, -9.0)]),
            (1, &[(1, 1.0), (2, 1.5)]),
            (2, &[(1, 2.0)]),
        ]);
        assert!(p.should_prune(0, &b));
    }

    #[test]
    fn median_rule_ties_survive() {
        // value == median must NOT prune (strictly-below rule).
        let p = MedianRule { warmup: 1 };
        let b = book(&[(0, &[(1, 1.0)]), (1, &[(1, 1.0)]), (2, &[(1, 1.0)])]);
        assert!(!p.should_prune(0, &b));
    }

    #[test]
    fn median_rule_ignores_future_steps_of_others() {
        let p = MedianRule { warmup: 1 };
        // Others' step-5 values are great, but at step <= 1 they were bad:
        // the comparison must use the comparable-step values only.
        let b = book(&[
            (0, &[(1, 0.0)]),
            (1, &[(1, -5.0), (5, 100.0)]),
            (2, &[(1, -4.0), (5, 100.0)]),
        ]);
        assert!(!p.should_prune(0, &b), "0.0 beats the step-1 median of -4.5");
    }

    #[test]
    fn asha_prunes_bottom_of_rung() {
        // r0 = 2, eta = 2: rung 0 at step 2. Four trials reach it; keep
        // floor(4 / 2) = 2. The two worst rung values prune.
        let p = AsyncSuccessiveHalving { r0: 2, eta: 2.0 };
        let b = book(&[
            (0, &[(1, 0.0), (2, 4.0)]),
            (1, &[(1, 0.0), (2, 3.0)]),
            (2, &[(1, 0.0), (2, 2.0)]),
            (3, &[(1, 0.0), (2, 1.0)]),
        ]);
        assert!(!p.should_prune(0, &b));
        assert!(!p.should_prune(1, &b));
        assert!(p.should_prune(2, &b));
        assert!(p.should_prune(3, &b));
    }

    #[test]
    fn asha_below_first_milestone_never_prunes() {
        let p = AsyncSuccessiveHalving { r0: 4, eta: 3.0 };
        let b = book(&[(0, &[(1, -100.0)]), (1, &[(1, 5.0)]), (2, &[(2, 5.0)])]);
        assert!(!p.should_prune(0, &b));
    }

    #[test]
    fn asha_judges_highest_reached_rung_only() {
        // r0 = 1, eta = 2: milestones 1, 2, 4. A trial at step 4 is judged
        // at rung 2, where only trials that reached step 4 compete.
        let p = AsyncSuccessiveHalving { r0: 1, eta: 2.0 };
        let b = book(&[
            // Worst at rung 0/1, but the only one at rung 2 so it's top-1.
            (0, &[(1, -9.0), (2, -9.0), (4, -9.0)]),
            (1, &[(1, 5.0), (2, 5.0)]),
            (2, &[(1, 4.0), (2, 4.0)]),
        ]);
        assert!(!p.should_prune(0, &b), "alone at its rung, keep = max(1, ..) saves it");
    }

    #[test]
    fn asha_ties_promote() {
        let p = AsyncSuccessiveHalving { r0: 1, eta: 2.0 };
        // Two trials, identical rung values: keep = max(1, floor(2/2)) = 1,
        // rank of each is 0 (no strictly-greater value) — both survive.
        let b = book(&[(0, &[(1, 1.0)]), (1, &[(1, 1.0)])]);
        assert!(!p.should_prune(0, &b));
        assert!(!p.should_prune(1, &b));
    }

    #[test]
    fn build_pruner_maps_kinds() {
        assert!(build_pruner(PrunerKind::None, 1, 3.0).is_none());
        assert_eq!(build_pruner(PrunerKind::Median, 2, 3.0).unwrap().name(), "median");
        assert_eq!(build_pruner(PrunerKind::Asha, 2, 3.0).unwrap().name(), "asha");
    }

    #[test]
    fn censored_value_is_worst_seen() {
        // Worse of (last report, worst history).
        assert_eq!(censored_value(-2.0, Some(-5.0)), Some(-5.0));
        assert_eq!(censored_value(-9.0, Some(-5.0)), Some(-9.0));
        // No history yet: the last report stands alone.
        assert_eq!(censored_value(-2.0, None), Some(-2.0));
        // NaN folds to -inf, then falls back to the finite history floor.
        assert_eq!(censored_value(f64::NAN, Some(-5.0)), Some(-5.0));
        assert_eq!(censored_value(f64::NEG_INFINITY, Some(-5.0)), Some(-5.0));
        // Nothing finite anywhere: no history contribution at all.
        assert_eq!(censored_value(f64::NAN, None), None);
        assert_eq!(censored_value(f64::NEG_INFINITY, None), None);
    }
}
