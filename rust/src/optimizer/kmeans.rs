//! k-means (k-means++ init, Lloyd iterations) over encoded candidate
//! vectors — the clustering substrate for the second batch algorithm.

use crate::linalg::Matrix;
use crate::util::rng::Pcg64;

/// Cluster assignment result.
pub struct KMeansResult {
    /// assignment[i] = cluster of row i.
    pub assignment: Vec<usize>,
    pub centroids: Matrix,
    pub k: usize,
}

fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Lloyd's algorithm with k-means++ seeding. `rows` is (n x d); panics if
/// n == 0; k is clamped to n.
pub fn kmeans(rows: &Matrix, k: usize, rng: &mut Pcg64, max_iter: usize) -> KMeansResult {
    let n = rows.rows();
    let d = rows.cols();
    assert!(n > 0, "kmeans over empty set");
    let k = k.clamp(1, n);

    // k-means++ seeding.
    let mut centroids = Matrix::zeros(k, d);
    let first = rng.uniform_usize(0, n);
    centroids.row_mut(0).copy_from_slice(rows.row(first));
    let mut d2: Vec<f64> = (0..n).map(|i| sq_dist(rows.row(i), centroids.row(0))).collect();
    for c in 1..k {
        let idx = rng.weighted_index(&d2);
        centroids.row_mut(c).copy_from_slice(rows.row(idx));
        for i in 0..n {
            d2[i] = d2[i].min(sq_dist(rows.row(i), centroids.row(c)));
        }
    }

    let mut assignment = vec![0usize; n];
    for iter in 0..max_iter {
        // Assign.
        let mut changed = false;
        for i in 0..n {
            let mut best = (f64::INFINITY, 0);
            for c in 0..k {
                let dd = sq_dist(rows.row(i), centroids.row(c));
                if dd < best.0 {
                    best = (dd, c);
                }
            }
            if assignment[i] != best.1 {
                assignment[i] = best.1;
                changed = true;
            }
        }
        // Always run at least one update (initial assignment may already
        // equal the all-zeros default without centroids being means).
        if !changed && iter > 0 {
            break;
        }
        // Update.
        let mut counts = vec![0usize; k];
        let mut sums = Matrix::zeros(k, d);
        for i in 0..n {
            let c = assignment[i];
            counts[c] += 1;
            for j in 0..d {
                sums[(c, j)] += rows[(i, j)];
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for j in 0..d {
                    centroids[(c, j)] = sums[(c, j)] / counts[c] as f64;
                }
            } else {
                // Re-seed empty cluster at the farthest point.
                let far = (0..n)
                    .max_by(|&a, &b| {
                        let da = sq_dist(rows.row(a), centroids.row(assignment[a]));
                        let db = sq_dist(rows.row(b), centroids.row(assignment[b]));
                        da.total_cmp(&db)
                    })
                    .unwrap();
                centroids.row_mut(c).copy_from_slice(rows.row(far));
            }
        }
    }
    KMeansResult { assignment, centroids, k }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(n_per: usize, centers: &[(f64, f64)], rng: &mut Pcg64) -> Matrix {
        let n = n_per * centers.len();
        let mut m = Matrix::zeros(n, 2);
        for (c, &(cx, cy)) in centers.iter().enumerate() {
            for i in 0..n_per {
                let r = c * n_per + i;
                m[(r, 0)] = cx + rng.normal() * 0.05;
                m[(r, 1)] = cy + rng.normal() * 0.05;
            }
        }
        m
    }

    #[test]
    fn separates_clear_blobs() {
        let mut rng = Pcg64::new(1);
        let rows = blobs(20, &[(0.0, 0.0), (5.0, 5.0), (0.0, 5.0)], &mut rng);
        let res = kmeans(&rows, 3, &mut rng, 50);
        // All members of a generated blob must share one cluster id.
        for blob in 0..3 {
            let ids: Vec<usize> =
                (0..20).map(|i| res.assignment[blob * 20 + i]).collect();
            assert!(ids.iter().all(|&x| x == ids[0]), "blob {blob} split: {ids:?}");
        }
        // And the three blobs use three distinct ids.
        let mut distinct: Vec<usize> =
            (0..3).map(|b| res.assignment[b * 20]).collect();
        distinct.sort_unstable();
        distinct.dedup();
        assert_eq!(distinct.len(), 3);
    }

    #[test]
    fn k_clamped_to_n() {
        let mut rng = Pcg64::new(2);
        let rows = Matrix::from_fn(3, 2, |i, j| (i + j) as f64);
        let res = kmeans(&rows, 10, &mut rng, 10);
        assert_eq!(res.k, 3);
    }

    #[test]
    fn single_cluster_centroid_is_mean() {
        let mut rng = Pcg64::new(3);
        let rows = Matrix::from_vec(4, 1, vec![1.0, 2.0, 3.0, 4.0]);
        let res = kmeans(&rows, 1, &mut rng, 10);
        assert!((res.centroids[(0, 0)] - 2.5).abs() < 1e-12);
        assert!(res.assignment.iter().all(|&a| a == 0));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Pcg64::new(9);
        let mut r2 = Pcg64::new(9);
        let rows = blobs(10, &[(0.0, 0.0), (3.0, 3.0)], &mut Pcg64::new(5));
        let a = kmeans(&rows, 2, &mut r1, 20);
        let b = kmeans(&rows, 2, &mut r2, 20);
        assert_eq!(a.assignment, b.assignment);
    }
}
