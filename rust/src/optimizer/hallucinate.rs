//! Batched GP-UCB with hallucinated observations (Desautels et al. 2014) —
//! the paper's first parallel algorithm.

use super::bayesian::BayesianCore;
use super::{BatchOptimizer, History};
use crate::gp::update::BatchHallucinator;
use crate::space::Config;
use crate::util::rng::Pcg64;
use anyhow::Result;

pub struct HallucinationOptimizer {
    core: BayesianCore,
}

impl HallucinationOptimizer {
    pub fn new(core: BayesianCore) -> Self {
        Self { core }
    }
}

impl BatchOptimizer for HallucinationOptimizer {
    fn propose(
        &mut self,
        history: &History,
        batch_size: usize,
        rng: &mut Pcg64,
    ) -> Result<Vec<Config>> {
        if history.len() < self.core.opts.initial_random.max(2) {
            // Cold start goes through the one shared sampling path (the
            // columnar sampler; bit-identical to the legacy sample_n
            // stream) — every batch here materializes anyway.
            return Ok(self.core.space.sample_columnar(rng, batch_size).into_configs());
        }
        let scored = self.core.fit_and_score(history, batch_size, rng)?;
        let mut hallucinator = BatchHallucinator::new(
            &scored.x_obs,
            &scored.xc,
            &scored.acq,
            &scored.params,
        );
        let mut batch = Vec::with_capacity(batch_size);
        for _ in 0..batch_size {
            match hallucinator.select_next() {
                // Only the winners are ever materialized into Configs.
                Some(idx) => batch.push(scored.cands.config(idx)),
                None => break, // candidate set exhausted (tiny spaces)
            }
        }
        // Guarantee the requested batch size even in degenerate cases.
        while batch.len() < batch_size {
            batch.push(self.core.space.sample(rng));
        }
        Ok(batch)
    }

    fn surrogate_capacity(&self) -> usize {
        self.core.max_obs()
    }

    fn rounds(&self) -> usize {
        self.core.rounds
    }

    fn rehydrate(&mut self, history: &History, rounds: usize) -> Result<()> {
        self.core.rehydrate(history, rounds)
    }

    fn rehydrate_pending(
        &mut self,
        history: &History,
        pending: &[Config],
        rounds: usize,
    ) -> Result<()> {
        self.core.rehydrate_pending(history, pending, rounds)
    }

    fn dist_cache_stats(&self) -> (u64, u64, u64) {
        self.core.dist_cache_stats()
    }

    fn name(&self) -> &'static str {
        "hallucination"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::GpOptions;
    use crate::space::{svm_space, SearchSpace};

    fn run_convergence(space: SearchSpace, f: impl Fn(&Config) -> f64, iters: usize) -> f64 {
        let core = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        let mut opt = HallucinationOptimizer::new(core);
        let mut rng = Pcg64::new(42);
        let mut h = History::new();
        for _ in 0..iters {
            let batch = opt.propose(&h, 1, &mut rng).unwrap();
            for cfg in batch {
                let v = f(&cfg);
                h.push(cfg, v);
            }
        }
        h.best().unwrap().1
    }

    #[test]
    fn converges_on_1d_quadratic_faster_than_random() {
        // maximize -(c-42)^2 over c in [0.01, 100]
        let space = svm_space();
        let best = run_convergence(space.clone(), |c| {
            let x = c.get_f64("c").unwrap();
            -(x - 42.0) * (x - 42.0)
        }, 25);
        // 25 GP-UCB evals should land within ~3 of the optimum (random
        // search: expected best ~ (100/26)^2 ≈ 15 away squared ≈ -3.7).
        assert!(best > -25.0, "best {best}");
    }

    #[test]
    fn batch_proposals_are_distinct() {
        let space = svm_space();
        let core = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        let mut opt = HallucinationOptimizer::new(core);
        let mut rng = Pcg64::new(7);
        let mut h = History::new();
        for cfg in space.sample_n(&mut rng, 6) {
            let v = -cfg.get_f64("c").unwrap();
            h.push(cfg, v);
        }
        let batch = opt.propose(&h, 5, &mut rng).unwrap();
        assert_eq!(batch.len(), 5);
        for i in 0..5 {
            for j in (i + 1)..5 {
                assert_ne!(batch[i], batch[j], "batch members must differ");
            }
        }
    }

    #[test]
    fn cold_start_is_random() {
        let space = svm_space();
        let core = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        let mut opt = HallucinationOptimizer::new(core);
        let mut rng = Pcg64::new(8);
        let batch = opt.propose(&History::new(), 3, &mut rng).unwrap();
        assert_eq!(batch.len(), 3);
    }

    /// The acceptance contract at the proposal level: the configs an
    /// optimizer proposes are byte-identical across every
    /// `proposal_shards` ∈ {0, 1, 3} × scheduler-kind (serial / threaded /
    /// celery-sim with fault fates firing) × `proposal_threads` setting —
    /// scoring distribution is a wall-clock knob, never a proposals knob.
    #[test]
    fn proposals_are_byte_identical_across_proposal_shards_and_schedulers() {
        use crate::gp::ShardExec;
        let space = svm_space();
        let mut h = History::new();
        let mut seed_rng = Pcg64::new(61);
        for cfg in space.sample_n(&mut seed_rng, 11) {
            let c = cfg.get_f64("c").unwrap();
            h.push(cfg, -(c - 42.0).abs());
        }
        let faulty = crate::scheduler::celery::CelerySimConfig {
            workers: 2,
            base_latency_ms: 0.05,
            straggler_prob: 0.3,
            straggler_factor: 1000.0,
            crash_prob: 0.3,
            result_timeout: std::time::Duration::from_millis(2),
        };
        let run = |shards: usize, threads: usize, exec: ShardExec| {
            let opts = crate::optimizer::GpOptions {
                proposal_shards: shards,
                proposal_threads: threads,
                shard_exec: exec,
                mc_samples: 193,
                ..Default::default()
            };
            let mut opt =
                HallucinationOptimizer::new(BayesianCore::new(space.clone(), opts).unwrap());
            opt.propose(&h, 3, &mut Pcg64::new(90)).unwrap()
        };
        let base = run(0, 1, ShardExec::Serial);
        assert_eq!(base.len(), 3);
        for shards in [0usize, 1, 3] {
            for exec in [
                ShardExec::Serial,
                ShardExec::Threaded,
                ShardExec::CelerySim { config: faulty.clone(), seed: 4 },
            ] {
                let batch = run(shards, 2, exec.clone());
                assert_eq!(
                    batch, base,
                    "shards={shards} {exec:?}: proposals must be byte-identical"
                );
            }
        }
    }

    #[test]
    fn pending_configs_suppress_their_neighborhood() {
        // propose_pending (constant-liar default) must steer the GP away
        // from an in-flight config: hallucinating an observation at the
        // acquisition's favorite point collapses its variance, so the next
        // proposal lands elsewhere.
        use crate::optimizer::BatchOptimizer;
        let space = svm_space();
        let core = BayesianCore::new(space.clone(), GpOptions::default()).unwrap();
        let mut opt = HallucinationOptimizer::new(core);
        let mut rng = Pcg64::new(19);
        let mut h = History::new();
        for cfg in space.sample_n(&mut rng, 10) {
            let c = cfg.get_f64("c").unwrap();
            h.push(cfg, -(c - 42.0).abs());
        }
        let favorite = opt.propose(&h, 1, &mut rng).unwrap().remove(0);
        let pending = vec![favorite.clone()];
        let next = opt.propose_pending(&h, &pending, 1, &mut rng).unwrap();
        assert!(!next.is_empty(), "one pending point can't exhaust the space");
        assert_ne!(next[0], favorite, "must not re-propose the in-flight config");
    }
}
