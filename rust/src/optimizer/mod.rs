//! Parallel optimization algorithms (paper §2.3).
//!
//! All optimizers implement [`BatchOptimizer`]: given the evaluation
//! [`History`], propose the next batch of configurations. Implemented
//! algorithms, matching the paper's list:
//!
//! * [`hallucinate::HallucinationOptimizer`] — batched GP-UCB with
//!   hallucinated observations (Desautels et al. 2014),
//! * [`cluster::ClusteringOptimizer`] — k-means clustering of the
//!   acquisition surface, max per cluster (Groves & Pyzer-Knapp 2018),
//! * [`random::RandomOptimizer`] — the random baseline,
//! * [`tpe::TpeOptimizer`] — Tree-structured Parzen Estimator, the in-repo
//!   Hyperopt comparator (DESIGN.md §2).
//!
//! Values in [`History`] are always in *maximization* convention — the
//! coordinator negates for minimization problems.

pub mod bayesian;
pub mod cluster;
pub mod hallucinate;
pub mod kmeans;
pub mod prune;
pub mod random;
pub mod thompson;
pub mod tpe;

use crate::space::{Config, SearchSpace};
use crate::util::rng::Pcg64;
use anyhow::Result;

/// Evaluation history: aligned (config, value) pairs, maximization values.
#[derive(Clone, Debug, Default)]
pub struct History {
    configs: Vec<Config>,
    values: Vec<f64>,
}

impl History {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, config: Config, value: f64) {
        self.configs.push(config);
        self.values.push(value);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn configs(&self) -> &[Config] {
        &self.configs
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Best (config, value) so far, maximization.
    pub fn best(&self) -> Option<(&Config, f64)> {
        crate::util::stats::argmax(&self.values).map(|i| (&self.configs[i], self.values[i]))
    }

    /// Keep only the most recent `cap` observations (artifact capacity).
    pub fn truncate_to_recent(&mut self, cap: usize) {
        if self.len() > cap {
            let cut = self.len() - cap;
            self.configs.drain(..cut);
            self.values.drain(..cut);
        }
    }

    /// A copy of the most recent `cap` observations — the surrogate view,
    /// without cloning the (unbounded) full history first.
    pub fn recent(&self, cap: usize) -> History {
        let start = self.len().saturating_sub(cap);
        History {
            configs: self.configs[start..].to_vec(),
            values: self.values[start..].to_vec(),
        }
    }
}

/// The constant-liar augmented surrogate view: `history` plus one
/// hallucinated observation (the mean observed value) per pending config,
/// clamped to `capacity` by dropping the oldest real observations. The
/// single construction shared by [`BatchOptimizer::propose_pending`] and
/// the GP optimizers' [`BatchOptimizer::rehydrate_pending`] — both must
/// build the *same* matrix or the post-resume warm state would never match
/// the first liar fit's rows.
pub(crate) fn liar_augmented(history: &History, pending: &[Config], capacity: usize) -> History {
    let liar = if history.is_empty() {
        0.0
    } else {
        crate::util::stats::mean(history.values())
    };
    let mut augmented = history.clone();
    for cfg in pending {
        augmented.push(cfg.clone(), liar);
    }
    // The hallucinated rows must still fit the surrogate: drop the
    // oldest real observations rather than overflowing a bounded
    // artifact backend (which would abort the whole run).
    augmented.truncate_to_recent(capacity);
    augmented
}

/// A batch-proposing optimizer.
pub trait BatchOptimizer {
    /// Propose `batch_size` configurations to evaluate next.
    fn propose(
        &mut self,
        history: &History,
        batch_size: usize,
        rng: &mut Pcg64,
    ) -> Result<Vec<Config>>;

    /// Propose conditioned on configs still *in flight* (the async event
    /// loop's refill path): the default wires the hallucinated-observation
    /// idea behind [`hallucinate`] into every optimizer as a constant-liar
    /// scheme (Ginsbourger et al. 2010) — each pending config is appended
    /// to the history with a hallucinated value (the mean observed value),
    /// so surrogate-based optimizers see collapsed variance there and steer
    /// proposals elsewhere. Exact duplicates of pending configs are
    /// filtered, so the result may be shorter than `batch_size` (callers
    /// top up from the space if needed).
    fn propose_pending(
        &mut self,
        history: &History,
        pending: &[Config],
        batch_size: usize,
        rng: &mut Pcg64,
    ) -> Result<Vec<Config>> {
        if pending.is_empty() {
            return self.propose(history, batch_size, rng);
        }
        let augmented = liar_augmented(history, pending, self.surrogate_capacity());
        let batch = self.propose(&augmented, batch_size, rng)?;
        Ok(batch.into_iter().filter(|c| !pending.contains(c)).collect())
    }

    /// Largest history window the optimizer's surrogate can absorb in one
    /// fit — the coordinator clamps its surrogate view to this, so a
    /// configured window can never overflow a smaller artifact manifest.
    /// `usize::MAX` for optimizers without a bounded surrogate.
    fn surrogate_capacity(&self) -> usize {
        usize::MAX
    }

    /// Behavior-affecting internal rounds counter (GP optimizers: the
    /// adaptive-beta schedule's clock). The coordinator journals it after
    /// every propose so a resumed run can restore the exact schedule
    /// position; optimizers without such state report 0.
    fn rounds(&self) -> usize {
        0
    }

    /// Restore internal state from a replayed journal: `history` is the
    /// reconstructed surrogate view (already clamped to the window the
    /// coordinator will fit next) and `rounds` the journaled counter.
    /// GP optimizers set their adaptive-beta clock and warm their
    /// incremental `CholeskyState` from the replayed rows — O(n²) per
    /// replayed observation via the append path (one factorization pass
    /// total), never an O(n³) refit per replayed event. The rebuilt factor
    /// is bit-identical to the one the uninterrupted run carried (the
    /// append/scratch equivalence property), so recovery cannot perturb
    /// post-resume proposals. Stateless optimizers ignore this.
    fn rehydrate(&mut self, _history: &History, _rounds: usize) -> Result<()> {
        Ok(())
    }

    /// [`rehydrate`](Self::rehydrate) for an async resume with work still
    /// in flight: GP optimizers warm their cached `CholeskyState` over the
    /// *constant-liar augmented* view `[history + pending]` — the exact
    /// matrix the first post-resume [`propose_pending`](Self::propose_pending)
    /// will fit — so that fit pays the O(n²)-per-row append path instead of
    /// a from-scratch O(n³) refactorization (the warm state reproduces what
    /// the crashed process's cache held after its last liar fit). The
    /// default ignores `pending` and delegates to `rehydrate`; the warm-up
    /// is a pure optimization either way (fits are bit-identical with or
    /// without it), so stateless optimizers lose nothing.
    fn rehydrate_pending(
        &mut self,
        history: &History,
        pending: &[Config],
        rounds: usize,
    ) -> Result<()> {
        let _ = pending;
        self.rehydrate(history, rounds)
    }

    /// Distance-cache lifecycle counters `(builds, appends, evicts)` since
    /// construction — full O(n·q·d) rebuilds, prefix-reusing appends, and
    /// (tiled mode) tiles dropped by truncate-and-regrow. Surfaced through
    /// [`crate::coordinator::results::TuningResult`] so cache-thrash
    /// regressions are observable instead of silent. Optimizers without a
    /// distance cache report zeros.
    fn dist_cache_stats(&self) -> (u64, u64, u64) {
        (0, 0, 0)
    }

    fn name(&self) -> &'static str;
}

/// Which optimizer to build (CLI / config string form).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerKind {
    Hallucination,
    Clustering,
    Random,
    Tpe,
    /// Batch Thompson sampling (extension; the paper's stated future work).
    Thompson,
}

impl OptimizerKind {
    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "hallucination" => Some(Self::Hallucination),
            "clustering" => Some(Self::Clustering),
            "random" => Some(Self::Random),
            "tpe" => Some(Self::Tpe),
            "thompson" => Some(Self::Thompson),
            _ => None,
        }
    }

    /// Inverse of [`from_str`](Self::from_str) (journal header round trip).
    pub fn as_str(self) -> &'static str {
        match self {
            Self::Hallucination => "hallucination",
            Self::Clustering => "clustering",
            Self::Random => "random",
            Self::Tpe => "tpe",
            Self::Thompson => "thompson",
        }
    }
}

/// Which surrogate backend the GP optimizers use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SurrogateBackend {
    /// AOT artifacts through PJRT (production path).
    Pjrt,
    /// Pure-Rust oracle (no artifacts needed).
    Native,
}

impl SurrogateBackend {
    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "pjrt" => Some(Self::Pjrt),
            "native" => Some(Self::Native),
            _ => None,
        }
    }

    /// Inverse of [`from_str`](Self::from_str) (journal header round trip).
    pub fn as_str(self) -> &'static str {
        match self {
            Self::Pjrt => "pjrt",
            Self::Native => "native",
        }
    }
}

/// How observed objective values are conditioned before the GP fit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum YTransform {
    /// Zero-mean / unit-variance scaling.
    Normalize,
    /// Rank-Gaussian (Gaussian copula) warp — robust to objective outliers
    /// (default; see [`crate::acq::rank_gauss`]).
    RankGauss,
}

/// Optimizer-level options shared by the GP algorithms.
#[derive(Clone, Debug)]
pub struct GpOptions {
    pub backend: SurrogateBackend,
    /// 0 = use the space's heuristic (paper §2.3).
    pub mc_samples: usize,
    /// Evaluations proposed at random before the surrogate engages.
    pub initial_random: usize,
    /// Grid-search the GP lengthscale by marginal likelihood each fit.
    pub tune_lengthscale: bool,
    pub noise: f64,
    /// Fixed exploration weight; None = adaptive schedule (paper default).
    pub fixed_beta: Option<f64>,
    pub y_transform: YTransform,
    /// Worker threads for Monte-Carlo candidate scoring (native backend
    /// only; the PJRT artifact path has its own execution model). 0 = one
    /// per available core. Scoring is chunked deterministically, so the
    /// acquisition output is byte-identical for every setting — this is a
    /// wall-clock knob, never a numerics knob.
    pub proposal_threads: usize,
    /// Scoring shards shipped through the scheduler's worker-pool
    /// machinery per propose round (native backend only). 0 = local-only
    /// chunked scoring (`proposal_threads` over `std::thread::scope`),
    /// byte-for-byte today's behavior; n ≥ 1 splits the candidate set into
    /// n fixed chunks executed as pool jobs under [`GpOptions::shard_exec`].
    /// Output is byte-identical for every setting — like
    /// `proposal_threads`, a wall-clock/scale knob, never a numerics knob.
    pub proposal_shards: usize,
    /// How scoring shards execute when `proposal_shards > 0` — the tuner
    /// mirrors its scheduler kind here (serial / threaded pool /
    /// celery-sim with fault fates).
    pub shard_exec: crate::gp::ShardExec,
    /// Arithmetic profile for the propose hot path (native backend only).
    /// `Exact` (default) keeps every bit-exactness contract; `Fast` trades
    /// bit-equality with Exact for SIMD-friendly chunked kernels and a
    /// tiled mixed-precision distance cache, while staying run-to-run
    /// deterministic and threads/shards-invariant (see README "Kernel
    /// profiles").
    pub kernel_profile: crate::gp::KernelProfile,
}

impl Default for GpOptions {
    fn default() -> Self {
        Self {
            backend: SurrogateBackend::Native,
            mc_samples: 0,
            initial_random: 2,
            tune_lengthscale: false,
            noise: 1e-3,
            fixed_beta: None,
            y_transform: YTransform::RankGauss,
            proposal_threads: 1,
            proposal_shards: 0,
            shard_exec: crate::gp::ShardExec::Serial,
            kernel_profile: crate::gp::KernelProfile::Exact,
        }
    }
}

/// Build an optimizer by kind.
pub fn build(
    kind: OptimizerKind,
    space: &SearchSpace,
    opts: &GpOptions,
) -> Result<Box<dyn BatchOptimizer>> {
    Ok(match kind {
        OptimizerKind::Random => Box::new(random::RandomOptimizer::new(space.clone())),
        OptimizerKind::Tpe => Box::new(tpe::TpeOptimizer::new(space.clone())),
        OptimizerKind::Hallucination => Box::new(hallucinate::HallucinationOptimizer::new(
            bayesian::BayesianCore::new(space.clone(), opts.clone())?,
        )),
        OptimizerKind::Clustering => Box::new(cluster::ClusteringOptimizer::new(
            bayesian::BayesianCore::new(space.clone(), opts.clone())?,
        )),
        OptimizerKind::Thompson => Box::new(thompson::ThompsonOptimizer::new(
            bayesian::BayesianCore::new(space.clone(), opts.clone())?,
        )),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::ParamValue;

    #[test]
    fn history_best_and_truncate() {
        let mut h = History::new();
        for (i, v) in [0.1, 0.9, 0.4].iter().enumerate() {
            h.push(
                Config::new(vec![("i".into(), ParamValue::Int(i as i64))]),
                *v,
            );
        }
        let (c, v) = h.best().unwrap();
        assert_eq!(v, 0.9);
        assert_eq!(c.get_i64("i"), Some(1));
        h.truncate_to_recent(2);
        assert_eq!(h.len(), 2);
        assert_eq!(h.configs()[0].get_i64("i"), Some(1));
        // recent() is the non-mutating window view
        let r = h.recent(1);
        assert_eq!(r.len(), 1);
        assert_eq!(r.configs()[0].get_i64("i"), Some(2));
        assert_eq!(h.len(), 2, "recent() must not mutate");
    }

    #[test]
    fn kind_parsing() {
        assert_eq!(OptimizerKind::from_str("hallucination"), Some(OptimizerKind::Hallucination));
        assert_eq!(OptimizerKind::from_str("clustering"), Some(OptimizerKind::Clustering));
        assert_eq!(OptimizerKind::from_str("tpe"), Some(OptimizerKind::Tpe));
        assert_eq!(OptimizerKind::from_str("random"), Some(OptimizerKind::Random));
        assert_eq!(OptimizerKind::from_str("sgd"), None);
    }

    #[test]
    fn build_all_kinds_native() {
        let space = crate::space::svm_space();
        for kind in [
            OptimizerKind::Random,
            OptimizerKind::Tpe,
            OptimizerKind::Hallucination,
            OptimizerKind::Clustering,
            OptimizerKind::Thompson,
        ] {
            let opt = build(kind, &space, &GpOptions::default()).unwrap();
            assert!(!opt.name().is_empty());
        }
    }

    #[test]
    fn propose_pending_never_duplicates_in_flight() {
        // A 4-point discrete space with 3 configs pending: any optimizer's
        // propose_pending must avoid the in-flight points entirely.
        let space = crate::space::SearchSpace::builder()
            .choice("arm", &["a", "b", "c", "d"])
            .build();
        let mut rng = Pcg64::new(71);
        let mut history = History::new();
        for (i, cfg) in space.sample_n(&mut rng, 8).into_iter().enumerate() {
            history.push(cfg, (i as f64 * 0.9).sin());
        }
        let pending: Vec<Config> = ["a", "b", "c"]
            .iter()
            .map(|v| Config::new(vec![("arm".into(), ParamValue::Str(v.to_string()))]))
            .collect();
        for kind in [
            OptimizerKind::Random,
            OptimizerKind::Tpe,
            OptimizerKind::Hallucination,
            OptimizerKind::Clustering,
            OptimizerKind::Thompson,
        ] {
            let opts = GpOptions { mc_samples: 64, ..Default::default() };
            let mut opt = build(kind, &space, &opts).unwrap();
            for round in 0..5 {
                let batch = opt
                    .propose_pending(&history, &pending, 2, &mut rng)
                    .unwrap();
                for cfg in &batch {
                    assert!(
                        !pending.contains(cfg),
                        "{kind:?} round {round}: re-proposed in-flight {cfg}"
                    );
                }
            }
        }
    }

    #[test]
    fn propose_pending_respects_surrogate_capacity() {
        // The hallucinated view (history + liar rows) must be clamped to
        // the surrogate's capacity, dropping the oldest real observations
        // instead of overflowing a bounded artifact backend.
        struct Probe {
            seen: usize,
        }
        impl BatchOptimizer for Probe {
            fn propose(
                &mut self,
                history: &History,
                _batch_size: usize,
                _rng: &mut Pcg64,
            ) -> Result<Vec<Config>> {
                self.seen = history.len();
                Ok(Vec::new())
            }
            fn surrogate_capacity(&self) -> usize {
                8
            }
            fn name(&self) -> &'static str {
                "probe"
            }
        }
        let space = crate::space::svm_space();
        let mut rng = Pcg64::new(5);
        let mut h = History::new();
        for cfg in space.sample_n(&mut rng, 10) {
            h.push(cfg, 0.0);
        }
        let pending = space.sample_n(&mut rng, 4);
        let mut probe = Probe { seen: 0 };
        probe.propose_pending(&h, &pending, 1, &mut rng).unwrap();
        assert_eq!(probe.seen, 8, "10 history + 4 liars clamped to capacity 8");
    }

    #[test]
    fn propose_pending_empty_pending_is_plain_propose() {
        let space = crate::space::svm_space();
        let mut opt = build(OptimizerKind::Random, &space, &GpOptions::default()).unwrap();
        let h = History::new();
        let a = opt.propose_pending(&h, &[], 3, &mut Pcg64::new(9)).unwrap();
        let b = opt.propose(&h, 3, &mut Pcg64::new(9)).unwrap();
        assert_eq!(a, b, "no pending: identical to propose with the same rng");
    }
}
