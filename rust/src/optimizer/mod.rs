//! Parallel optimization algorithms (paper §2.3).
//!
//! All optimizers implement [`BatchOptimizer`]: given the evaluation
//! [`History`], propose the next batch of configurations. Implemented
//! algorithms, matching the paper's list:
//!
//! * [`hallucinate::HallucinationOptimizer`] — batched GP-UCB with
//!   hallucinated observations (Desautels et al. 2014),
//! * [`cluster::ClusteringOptimizer`] — k-means clustering of the
//!   acquisition surface, max per cluster (Groves & Pyzer-Knapp 2018),
//! * [`random::RandomOptimizer`] — the random baseline,
//! * [`tpe::TpeOptimizer`] — Tree-structured Parzen Estimator, the in-repo
//!   Hyperopt comparator (DESIGN.md §2).
//!
//! Values in [`History`] are always in *maximization* convention — the
//! coordinator negates for minimization problems.

pub mod bayesian;
pub mod cluster;
pub mod hallucinate;
pub mod kmeans;
pub mod random;
pub mod thompson;
pub mod tpe;

use crate::space::{Config, SearchSpace};
use crate::util::rng::Pcg64;
use anyhow::Result;

/// Evaluation history: aligned (config, value) pairs, maximization values.
#[derive(Clone, Debug, Default)]
pub struct History {
    configs: Vec<Config>,
    values: Vec<f64>,
}

impl History {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, config: Config, value: f64) {
        self.configs.push(config);
        self.values.push(value);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn configs(&self) -> &[Config] {
        &self.configs
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// Best (config, value) so far, maximization.
    pub fn best(&self) -> Option<(&Config, f64)> {
        crate::util::stats::argmax(&self.values).map(|i| (&self.configs[i], self.values[i]))
    }

    /// Keep only the most recent `cap` observations (artifact capacity).
    pub fn truncate_to_recent(&mut self, cap: usize) {
        if self.len() > cap {
            let cut = self.len() - cap;
            self.configs.drain(..cut);
            self.values.drain(..cut);
        }
    }
}

/// A batch-proposing optimizer.
pub trait BatchOptimizer {
    /// Propose `batch_size` configurations to evaluate next.
    fn propose(
        &mut self,
        history: &History,
        batch_size: usize,
        rng: &mut Pcg64,
    ) -> Result<Vec<Config>>;

    fn name(&self) -> &'static str;
}

/// Which optimizer to build (CLI / config string form).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerKind {
    Hallucination,
    Clustering,
    Random,
    Tpe,
    /// Batch Thompson sampling (extension; the paper's stated future work).
    Thompson,
}

impl OptimizerKind {
    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "hallucination" => Some(Self::Hallucination),
            "clustering" => Some(Self::Clustering),
            "random" => Some(Self::Random),
            "tpe" => Some(Self::Tpe),
            "thompson" => Some(Self::Thompson),
            _ => None,
        }
    }
}

/// Which surrogate backend the GP optimizers use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SurrogateBackend {
    /// AOT artifacts through PJRT (production path).
    Pjrt,
    /// Pure-Rust oracle (no artifacts needed).
    Native,
}

impl SurrogateBackend {
    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "pjrt" => Some(Self::Pjrt),
            "native" => Some(Self::Native),
            _ => None,
        }
    }
}

/// How observed objective values are conditioned before the GP fit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum YTransform {
    /// Zero-mean / unit-variance scaling.
    Normalize,
    /// Rank-Gaussian (Gaussian copula) warp — robust to objective outliers
    /// (default; see [`crate::acq::rank_gauss`]).
    RankGauss,
}

/// Optimizer-level options shared by the GP algorithms.
#[derive(Clone, Debug)]
pub struct GpOptions {
    pub backend: SurrogateBackend,
    /// 0 = use the space's heuristic (paper §2.3).
    pub mc_samples: usize,
    /// Evaluations proposed at random before the surrogate engages.
    pub initial_random: usize,
    /// Grid-search the GP lengthscale by marginal likelihood each fit.
    pub tune_lengthscale: bool,
    pub noise: f64,
    /// Fixed exploration weight; None = adaptive schedule (paper default).
    pub fixed_beta: Option<f64>,
    pub y_transform: YTransform,
}

impl Default for GpOptions {
    fn default() -> Self {
        Self {
            backend: SurrogateBackend::Native,
            mc_samples: 0,
            initial_random: 2,
            tune_lengthscale: false,
            noise: 1e-3,
            fixed_beta: None,
            y_transform: YTransform::RankGauss,
        }
    }
}

/// Build an optimizer by kind.
pub fn build(
    kind: OptimizerKind,
    space: &SearchSpace,
    opts: &GpOptions,
) -> Result<Box<dyn BatchOptimizer>> {
    Ok(match kind {
        OptimizerKind::Random => Box::new(random::RandomOptimizer::new(space.clone())),
        OptimizerKind::Tpe => Box::new(tpe::TpeOptimizer::new(space.clone())),
        OptimizerKind::Hallucination => Box::new(hallucinate::HallucinationOptimizer::new(
            bayesian::BayesianCore::new(space.clone(), opts.clone())?,
        )),
        OptimizerKind::Clustering => Box::new(cluster::ClusteringOptimizer::new(
            bayesian::BayesianCore::new(space.clone(), opts.clone())?,
        )),
        OptimizerKind::Thompson => Box::new(thompson::ThompsonOptimizer::new(
            bayesian::BayesianCore::new(space.clone(), opts.clone())?,
        )),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::ParamValue;

    #[test]
    fn history_best_and_truncate() {
        let mut h = History::new();
        for (i, v) in [0.1, 0.9, 0.4].iter().enumerate() {
            h.push(
                Config::new(vec![("i".into(), ParamValue::Int(i as i64))]),
                *v,
            );
        }
        let (c, v) = h.best().unwrap();
        assert_eq!(v, 0.9);
        assert_eq!(c.get_i64("i"), Some(1));
        h.truncate_to_recent(2);
        assert_eq!(h.len(), 2);
        assert_eq!(h.configs()[0].get_i64("i"), Some(1));
    }

    #[test]
    fn kind_parsing() {
        assert_eq!(OptimizerKind::from_str("hallucination"), Some(OptimizerKind::Hallucination));
        assert_eq!(OptimizerKind::from_str("clustering"), Some(OptimizerKind::Clustering));
        assert_eq!(OptimizerKind::from_str("tpe"), Some(OptimizerKind::Tpe));
        assert_eq!(OptimizerKind::from_str("random"), Some(OptimizerKind::Random));
        assert_eq!(OptimizerKind::from_str("sgd"), None);
    }

    #[test]
    fn build_all_kinds_native() {
        let space = crate::space::svm_space();
        for kind in [
            OptimizerKind::Random,
            OptimizerKind::Tpe,
            OptimizerKind::Hallucination,
            OptimizerKind::Clustering,
            OptimizerKind::Thompson,
        ] {
            let opt = build(kind, &space, &GpOptions::default()).unwrap();
            assert!(!opt.name().is_empty());
        }
    }
}
