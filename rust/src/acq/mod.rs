//! Acquisition machinery: the adaptive UCB exploration schedule and the
//! Monte-Carlo candidate generation the paper describes in §2.3.

use crate::space::{ColumnarSet, SearchSpace};
use crate::util::rng::Pcg64;
use crate::util::stats::nan_as_worst;

/// Adaptive exploration weight (paper: "adaptive exploitation vs exploration
/// trade-off as a function of search space size, number of evaluations, and
/// parallel batch size").
///
/// GP-UCB theory (Srinivas et al.; Desautels et al. for batches) sets
/// β_t = 2 log(|D| t² π² / 6δ). We use its square root (our UCB multiplies
/// σ, not σ²), grow t by whole batches (each batch is one information
/// round), and clamp to a practical band so early iterations are not
/// absurdly exploratory.
pub fn adaptive_beta(iteration: usize, cardinality: f64, batch_size: usize) -> f64 {
    let t = (iteration + 1) as f64;
    let d = cardinality.max(2.0);
    let delta = 0.1;
    let raw = 2.0 * (d.ln() + 2.0 * t.ln() + (std::f64::consts::PI.powi(2) / (6.0 * delta)).ln());
    // Batched selection hallucinates k-1 points per round; slightly larger
    // beta compensates for the information lag (Desautels' C-factor).
    let batch_boost = 1.0 + 0.05 * (batch_size.saturating_sub(1) as f64).sqrt();
    (raw.sqrt() * 0.4 * batch_boost).clamp(1.0, 4.0)
}

/// Monte-Carlo candidate set: valid configurations sampled from the space's
/// own distributions (the acquisition is only evaluated at valid points —
/// the paper's treatment of discrete/categorical variables). Generated in
/// **columnar** form ([`SearchSpace::sample_columnar`]): typed SoA columns
/// plus the encoded matrix, no per-candidate `Config` — values are
/// bit-identical to the legacy `sample_n` stream, and only the argmax
/// winners are ever materialized.
pub fn mc_candidates(space: &SearchSpace, n_override: usize, rng: &mut Pcg64) -> ColumnarSet {
    let n = if n_override > 0 { n_override } else { space.mc_samples_heuristic() };
    space.sample_columnar(rng, n)
}

/// Expected improvement at a (mean, var) pair given the incumbent best
/// (maximization). Provided as an alternative acquisition (extension; the
/// paper's algorithms use UCB).
pub fn expected_improvement(mean: f64, var: f64, best: f64) -> f64 {
    let sigma = var.sqrt();
    if sigma < 1e-12 {
        return (mean - best).max(0.0);
    }
    let z = (mean - best) / sigma;
    (mean - best) * norm_cdf(z) + sigma * norm_pdf(z)
}

fn norm_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Φ⁻¹(p) via Acklam's rational approximation (|rel err| < 1.15e-9).
pub fn norm_ppf(p: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&p));
    let p = p.clamp(1e-12, 1.0 - 1e-12);
    const A: [f64; 6] = [
        -3.969683028665376e+01, 2.209460984245205e+02, -2.759285104469687e+02,
        1.383577518672690e+02, -3.066479806614716e+01, 2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01, 1.615858368580409e+02, -1.556989798598866e+02,
        6.680131188771972e+01, -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e+00,
        -2.549732539343734e+00, 4.374664141464968e+00, 2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let p_low = 0.02425;
    if p < p_low {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - p_low {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        -norm_ppf(1.0 - p)
    }
}

/// Rank-Gaussian (Gaussian copula) transform of objective values: maps the
/// i-th ranked value to Φ⁻¹((rank + 0.5)/n). Robust to the huge outliers
/// objective landscapes like Branin produce (a 300x outlier would otherwise
/// compress the whole interesting region into a flat GP).
pub fn rank_gauss(y: &[f64]) -> Vec<f64> {
    let n = y.len();
    if n == 0 {
        return Vec::new();
    }
    let mut order: Vec<usize> = (0..n).collect();
    // NaNs (possible via hand-edited history dumps that bypass the tuner's
    // is_finite guard) sort as the worst rank instead of panicking — and
    // instead of total_cmp's NaN-after-+inf order, which would hand the
    // corrupt observation the best rank.
    order.sort_by(|&a, &b| nan_as_worst(y[a]).total_cmp(&nan_as_worst(y[b])));
    let mut out = vec![0.0; n];
    let mut i = 0;
    while i < n {
        // average ranks over ties so equal values map identically
        let mut j = i;
        while j + 1 < n && y[order[j + 1]] == y[order[i]] {
            j += 1;
        }
        let rank = (i + j) as f64 / 2.0;
        let z = norm_ppf((rank + 0.5) / n as f64);
        for &idx in &order[i..=j] {
            out[idx] = z;
        }
        i = j + 1;
    }
    out
}

/// Φ(z) via Abramowitz–Stegun 7.1.26 (|err| < 7.5e-8).
pub fn norm_cdf(z: f64) -> f64 {
    let x = z / std::f64::consts::SQRT_2;
    let t = 1.0 / (1.0 + 0.3275911 * x.abs());
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    let erf = if x >= 0.0 { y } else { -y };
    0.5 * (1.0 + erf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::xgboost_space;

    #[test]
    fn beta_grows_with_time_and_space() {
        let b1 = adaptive_beta(1, 1e3, 1);
        let b10 = adaptive_beta(10, 1e3, 1);
        assert!(b10 >= b1);
        let big = adaptive_beta(1, 1e9, 1);
        assert!(big >= b1);
        for t in 0..100 {
            let b = adaptive_beta(t, 1e6, 5);
            assert!((1.0..=4.0).contains(&b));
        }
    }

    #[test]
    fn beta_batch_boost() {
        assert!(adaptive_beta(5, 1e6, 10) > adaptive_beta(5, 1e6, 1));
    }

    #[test]
    fn mc_candidates_sizes() {
        let s = xgboost_space();
        let mut rng = Pcg64::new(1);
        assert_eq!(mc_candidates(&s, 123, &mut rng).len(), 123);
        let heuristic = mc_candidates(&s, 0, &mut rng).len();
        assert_eq!(heuristic, s.mc_samples_heuristic());
    }

    #[test]
    fn mc_candidates_match_the_legacy_stream() {
        // The columnar candidate set draws the exact RNG sequence the
        // legacy sample_n path drew: same seed, same candidate values.
        let s = xgboost_space();
        let set = mc_candidates(&s, 57, &mut Pcg64::new(44));
        let legacy = s.sample_n(&mut Pcg64::new(44), 57);
        assert_eq!(set.len(), legacy.len());
        for (i, want) in legacy.iter().enumerate() {
            assert_eq!(&set.config(i), want, "candidate {i}");
        }
    }

    #[test]
    fn ei_properties() {
        assert!(expected_improvement(1.0, 1.0, 0.0) > expected_improvement(0.0, 1.0, 0.0));
        assert_eq!(expected_improvement(2.0, 0.0, 1.0), 1.0);
        assert_eq!(expected_improvement(0.5, 0.0, 1.0), 0.0);
        assert!(expected_improvement(0.0, 1.0, 0.5) > 0.0);
    }

    #[test]
    fn norm_cdf_sanity() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((norm_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((norm_cdf(-1.96) - 0.025).abs() < 1e-3);
    }

    #[test]
    fn ppf_inverts_cdf() {
        for p in [0.001, 0.025, 0.2, 0.5, 0.8, 0.975, 0.999] {
            let z = norm_ppf(p);
            assert!((norm_cdf(z) - p).abs() < 1e-6, "p={p} z={z}");
        }
        assert_eq!(norm_ppf(0.5), 0.0);
    }

    #[test]
    fn rank_gauss_properties() {
        // Monotone, zero-mean-ish, outlier-bounded.
        let y = [1.0, 2.0, 3.0, 300.0]; // huge outlier
        let z = rank_gauss(&y);
        assert!(z[0] < z[1] && z[1] < z[2] && z[2] < z[3]);
        assert!(z[3] < 2.0, "outlier must be bounded, got {}", z[3]);
        assert!(z.iter().sum::<f64>().abs() < 1e-9, "symmetric ranks");
        // ties map identically
        let zt = rank_gauss(&[1.0, 1.0, 5.0]);
        assert_eq!(zt[0], zt[1]);
        assert!(zt[2] > zt[0]);
        assert!(rank_gauss(&[]).is_empty());
    }

    #[test]
    fn rank_gauss_tolerates_nan_values() {
        // Regression: the rank sort used partial_cmp().unwrap() and
        // panicked on NaN (reachable via hand-edited history dumps that
        // bypass the tuner's is_finite guard). A NaN must take the WORST
        // rank (maximization), never the best; finite values keep their
        // ordering and every output stays finite (it's a rank transform).
        let y = [0.5, f64::NAN, -1.0, 2.0];
        let z = rank_gauss(&y);
        assert_eq!(z.len(), 4);
        assert!(z[2] < z[0] && z[0] < z[3], "finite ordering preserved");
        assert!(z[1] < z[2], "NaN must rank below every finite value");
        assert!(z.iter().all(|v| v.is_finite()));
    }
}
