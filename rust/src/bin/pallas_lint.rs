//! `pallas-lint` — the static contract checker, as a CI-runnable binary.
//!
//! ```text
//! cargo run --bin pallas-lint -- [--deny] [--json] [--write-baseline]
//!                                [--root <src-dir>] [--baseline <file>]
//! ```
//!
//! * default: scan, print findings (human text), exit 0.
//! * `--deny`: exit 1 on any new (non-pragma'd, non-baselined) finding —
//!   the CI mode. Stale baseline entries warn but do not fail; the test
//!   suite pins the baseline count so it can only shrink.
//! * `--json`: machine-readable report on stdout.
//! * `--write-baseline`: grandfather every current finding into the
//!   baseline file and exit (entries get a generic reason — edit in a real
//!   justification, or better, fix/pragma the finding).
//! * `--root`: the source root to scan (default: auto-locate `rust/src`
//!   from the working directory, falling back to the compile-time crate
//!   dir, so it works from the workspace root, from `rust/`, and from CI).
//! * `--baseline`: baseline path (default: `<root>/../lint-baseline.json`,
//!   i.e. `rust/lint-baseline.json`).

use mango::lint::{self, report, Baseline};
use std::path::PathBuf;
use std::process::ExitCode;

struct Args {
    root: Option<PathBuf>,
    baseline: Option<PathBuf>,
    json: bool,
    deny: bool,
    write_baseline: bool,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args {
        root: None,
        baseline: None,
        json: false,
        deny: false,
        write_baseline: false,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--json" => args.json = true,
            "--deny" => args.deny = true,
            "--write-baseline" => args.write_baseline = true,
            "--root" => {
                args.root = Some(PathBuf::from(
                    it.next().ok_or("--root needs a directory argument")?,
                ))
            }
            "--baseline" => {
                args.baseline = Some(PathBuf::from(
                    it.next().ok_or("--baseline needs a file argument")?,
                ))
            }
            "--help" | "-h" => {
                println!(
                    "pallas-lint [--deny] [--json] [--write-baseline] \
                     [--root <src-dir>] [--baseline <file>]"
                );
                std::process::exit(0);
            }
            other => return Err(format!("unknown argument `{other}`")),
        }
    }
    Ok(args)
}

/// Locate `rust/src` without assuming the working directory: workspace
/// root and `rust/` both work, and the compile-time manifest dir is the
/// backstop for odd CI layouts.
fn locate_src_root() -> Option<PathBuf> {
    let cwd = std::env::current_dir().ok()?;
    for cand in [cwd.join("rust/src"), cwd.join("src")] {
        // `lib.rs` distinguishes the real source root from e.g. a stray
        // `src/` directory elsewhere.
        if cand.join("lib.rs").is_file() {
            return Some(cand);
        }
    }
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
    manifest.join("lib.rs").is_file().then_some(manifest)
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("pallas-lint: {e}");
            return ExitCode::from(2);
        }
    };
    let Some(root) = args.root.or_else(locate_src_root) else {
        eprintln!(
            "pallas-lint: could not locate the source root (run from the \
             workspace root or pass --root rust/src)"
        );
        return ExitCode::from(2);
    };
    let baseline_path = args
        .baseline
        .unwrap_or_else(|| root.parent().unwrap_or(&root).join("lint-baseline.json"));

    if args.write_baseline {
        let report = match lint::lint_tree(&root, None) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("pallas-lint: scanning {}: {e}", root.display());
                return ExitCode::from(2);
            }
        };
        let b = Baseline::from_findings(
            &report.findings,
            "grandfathered by --write-baseline; fix or pragma before touching this line",
        );
        if let Err(e) = b.save(&baseline_path) {
            eprintln!("pallas-lint: {e}");
            return ExitCode::from(2);
        }
        println!(
            "pallas-lint: wrote {} entr{} to {}",
            b.entries.len(),
            if b.entries.len() == 1 { "y" } else { "ies" },
            baseline_path.display()
        );
        return ExitCode::SUCCESS;
    }

    let baseline = if baseline_path.is_file() {
        match Baseline::load(&baseline_path) {
            Ok(b) => Some(b),
            Err(e) => {
                eprintln!("pallas-lint: {e}");
                return ExitCode::from(2);
            }
        }
    } else {
        None
    };
    let report = match lint::lint_tree(&root, baseline.as_ref()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("pallas-lint: scanning {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    if args.json {
        print!("{}", report::json(&report));
    } else {
        print!("{}", report::human(&report));
    }
    if args.deny && !report.is_clean() {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
