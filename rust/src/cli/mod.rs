//! Command-line argument parsing (no clap in the offline registry).
//!
//! Grammar: `mango <subcommand> [--flag value | --switch] ...`.

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// Flags that take no value.
const SWITCHES: [&str; 6] =
    ["json", "verbose", "tune-lengthscale", "help", "resume", "compact-on-resume"];

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = it.next().unwrap();
            }
        }
        while let Some(arg) = it.next() {
            let Some(name) = arg.strip_prefix("--") else {
                return Err(anyhow!("unexpected positional argument '{arg}'"));
            };
            if SWITCHES.contains(&name) {
                out.switches.push(name.to_string());
            } else {
                let value = it
                    .next()
                    .ok_or_else(|| anyhow!("flag --{name} expects a value"))?;
                out.flags.insert(name.to_string(), value);
            }
        }
        Ok(out)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, flag: &str) -> Option<&str> {
        self.flags.get(flag).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, flag: &str, default: &'a str) -> &'a str {
        self.get(flag).unwrap_or(default)
    }

    pub fn get_usize(&self, flag: &str, default: usize) -> Result<usize> {
        match self.get(flag) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{flag}: '{v}' is not an integer")),
        }
    }

    pub fn get_u64(&self, flag: &str, default: u64) -> Result<u64> {
        match self.get(flag) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{flag}: '{v}' is not an integer")),
        }
    }

    pub fn get_f64(&self, flag: &str, default: f64) -> Result<f64> {
        match self.get(flag) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{flag}: '{v}' is not a number")),
        }
    }

    /// Error on flags the subcommand doesn't understand.
    pub fn ensure_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                return Err(anyhow!("unknown flag --{k} (known: {known:?})"));
            }
        }
        Ok(())
    }
}

/// The CLI usage text.
pub const USAGE: &str = "\
mango — parallel hyperparameter tuning (MANGO reproduction)

USAGE:
  mango tune --workload <name> [options]   run one tuning job
  mango experiment --config <file.json>    run a repeated experiment
  mango list                               list workloads/optimizers/schedulers
  mango info                               show artifact + platform info

TUNE OPTIONS:
  --workload <name>        wine_gbt | knn_wine | svm_wine | branin |
                           mixed_branin | rosenbrock | ackley | hartmann6
  --optimizer <name>       hallucination | clustering | random | tpe | thompson
  --scheduler <name>       serial | threaded | celery        [serial]
  --backend <name>         pjrt | native                     [pjrt]
  --mode <name>            sync (batch barriers) | async (event loop) [sync]
  --batch-size <k>         configurations per iteration      [1]
  --iterations <n>         optimizer iterations (batches)    [60]
  --initial-random <n>     random evals before surrogate     [2]
  --workers <n>            parallel workers                  [batch size]
  --async-window <n>       async in-flight window (0 = max(batch, workers))
  --max-retries <n>        async retries per lost evaluation [2]
  --mc-samples <n>         MC acquisition samples (0 = heuristic)
  --proposal-threads <n>   candidate-scoring threads, native backend
                           (0 = one per core; output is byte-identical
                           for every setting)                [1]
  --proposal-shards <n>    candidate-scoring shards shipped through the
                           run's scheduler machinery, native backend
                           (0 = local-only; output is byte-identical
                           for every setting)                [0]
  --kernel-profile <name>  exact (bit-exact contracts) | fast (chunked
                           SIMD-friendly kernels + tiled distance cache;
                           deterministic, ~1e-10 of exact)   [exact]
  --seed <s>               RNG seed                          [0]
  --pruner <name>          trial-level early stopping on intermediate
                           reports, async mode only:
                           none | median | asha              [none]
  --pruner-warmup <n>      reports before the median rule may prune, or
                           the ASHA first-rung budget r0     [1]
  --asha-reduction <eta>   ASHA reduction factor (> 1)       [3]
  --early-stop <n>         stop after n iterations without improvement
  --max-surrogate-obs <n>  history window the GP sees        [512]
  --tune-lengthscale       GP lengthscale by marginal likelihood
  --journal <file.jsonl>   record a crash-safe run journal (starting a run
                           truncates an existing file at this path)
  --fsync-every <n>        fsync the journal every n appends for machine-
                           crash durability (0 = flush-only) [0]
  --journal-on-error <p>   journal write-error policy: fail-stop (abort
                           with the cause) | degrade (log once, finish the
                           run without persistence)          [fail-stop]
  --journal-segment-events <n>
                           seal + rotate the journal to a new segment file
                           every n events; sealed segments carry a footer
                           checksum (0 = single-file layout) [0]
  --journal-keep-segments <n>
                           sealed segments compaction leaves behind the
                           active one — the warm tail a resume replays
                           event-by-event                    [2]
  --compact-on-resume      fold the sealed segment prefix into one
                           checkpoint record before reopening the journal
                           (with --resume; resume cost and disk footprint
                           become O(active window))
  --resume                 resume the run recorded in --journal (the journal
                           header supplies the config; other tune flags are
                           ignored); with a fixed seed the resumed run
                           reproduces the uninterrupted result
  --replay <order>         async completion-folding order: wallclock
                           (arrival order) | stable (ascending task id —
                           the trajectory is byte-identical run-to-run,
                           across schedulers, and across crash+resume)
                                                             [wallclock]
  --retry-backoff-ms <ms>  base delay before resubmitting a lost task;
                           doubles per attempt (capped at 64x) with
                           seed-deterministic jitter (0 = immediate) [0]
  --stall-timeout-ms <ms>  abandon in-flight work and return partial
                           results after this long without any completion
                           (0 = wait forever)                [3600000]
  --json                   machine-readable output
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<Args> {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = parse("tune --workload branin --batch-size 5 --json").unwrap();
        assert_eq!(a.subcommand, "tune");
        assert_eq!(a.get("workload"), Some("branin"));
        assert_eq!(a.get_usize("batch-size", 1).unwrap(), 5);
        assert!(a.has("json"));
        assert!(!a.has("verbose"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(parse("tune --workload").is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("tune --batch-size five").unwrap();
        assert!(a.get_usize("batch-size", 1).is_err());
    }

    #[test]
    fn float_flags_parse_with_default() {
        let a = parse("tune --asha-reduction 2.5").unwrap();
        assert_eq!(a.get_f64("asha-reduction", 3.0).unwrap(), 2.5);
        assert_eq!(a.get_f64("absent", 3.0).unwrap(), 3.0);
        let a = parse("tune --asha-reduction eta").unwrap();
        assert!(a.get_f64("asha-reduction", 3.0).is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse("tune --bogus 1").unwrap();
        assert!(a.ensure_known(&["workload"]).is_err());
        assert!(a.ensure_known(&["bogus"]).is_ok());
    }

    #[test]
    fn defaults() {
        let a = parse("tune").unwrap();
        assert_eq!(a.get_or("optimizer", "hallucination"), "hallucination");
        assert_eq!(a.get_u64("seed", 0).unwrap(), 0);
    }
}
