//! Parameter values and configurations (the `params` dicts of the paper).

use crate::config::json::Json;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::fmt;

/// Canonical, round-trip-stable JSON encoding of one `f64` (the run
/// journal's number codec). Finite values other than `-0.0` serialize as
/// plain JSON numbers — Rust's shortest-round-trip `Display` plus a
/// correctly-rounded `parse` make the decimal form bit-exact. Values a
/// JSON number cannot carry faithfully (`NaN` with any payload, `±inf`,
/// `-0.0` — which [`Json::Num`]'s integer-style printing would collapse to
/// `0`) serialize as the IEEE-754 bit pattern, so every one of the 2^64
/// possible values survives serialize → parse → re-serialize bit-identically.
pub fn f64_to_json(v: f64) -> Json {
    if v.is_finite() && !(v == 0.0 && v.is_sign_negative()) {
        Json::Num(v)
    } else {
        Json::Str(format!("f64:{:016x}", v.to_bits()))
    }
}

/// Decode [`f64_to_json`]'s encoding.
pub fn f64_from_json(j: &Json) -> Result<f64> {
    match j {
        Json::Num(n) => Ok(*n),
        Json::Str(s) => {
            let hex = s
                .strip_prefix("f64:")
                .ok_or_else(|| anyhow!("bad f64 encoding '{s}'"))?;
            let bits = u64::from_str_radix(hex, 16)
                .map_err(|e| anyhow!("bad f64 bits '{s}': {e}"))?;
            Ok(f64::from_bits(bits))
        }
        other => Err(anyhow!("expected f64 encoding, found {other}")),
    }
}

/// One hyperparameter value.
#[derive(Clone, Debug, PartialEq)]
pub enum ParamValue {
    F64(f64),
    Int(i64),
    Str(String),
}

impl ParamValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            ParamValue::F64(v) => Some(*v),
            ParamValue::Int(v) => Some(*v as f64),
            ParamValue::Str(_) => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            ParamValue::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            ParamValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            ParamValue::F64(v) => Json::Num(*v),
            ParamValue::Int(v) => Json::Num(*v as f64),
            ParamValue::Str(s) => Json::Str(s.clone()),
        }
    }

    /// Canonical journal encoding: a single-key object tagging the variant
    /// (`{"f":…}` / `{"i":…}` / `{"s":…}`), with floats via [`f64_to_json`]
    /// (bit-exact incl. NaN payloads, `±inf`, `-0.0`) and integers as
    /// numbers only while exactly representable in a JSON double.
    pub fn to_journal_json(&self) -> Json {
        match self {
            ParamValue::F64(v) => Json::obj(vec![("f", f64_to_json(*v))]),
            ParamValue::Int(i) => {
                let enc = if i.unsigned_abs() <= (1u64 << 53) {
                    Json::Num(*i as f64)
                } else {
                    Json::Str(format!("i64:{i}"))
                };
                Json::obj(vec![("i", enc)])
            }
            ParamValue::Str(s) => Json::obj(vec![("s", Json::Str(s.clone()))]),
        }
    }

    /// Decode [`to_journal_json`](Self::to_journal_json)'s encoding.
    pub fn from_journal_json(j: &Json) -> Result<Self> {
        let obj = j
            .as_obj()
            .filter(|m| m.len() == 1)
            .ok_or_else(|| anyhow!("param value must be a single-key object, found {j}"))?;
        let (tag, val) = obj.iter().next().unwrap();
        match tag.as_str() {
            "f" => Ok(ParamValue::F64(f64_from_json(val)?)),
            "i" => match val {
                Json::Num(n) => {
                    // Mirror the encoder's 2^53 cutoff: a fractional or
                    // out-of-range number here is journal corruption and
                    // must fail loudly, not truncate/saturate into a
                    // silently different config.
                    anyhow::ensure!(
                        n.fract() == 0.0 && n.abs() <= 9_007_199_254_740_992.0,
                        "i64 encoding not an exactly-representable integer: {n}"
                    );
                    Ok(ParamValue::Int(*n as i64))
                }
                Json::Str(s) => {
                    let digits = s
                        .strip_prefix("i64:")
                        .ok_or_else(|| anyhow!("bad i64 encoding '{s}'"))?;
                    Ok(ParamValue::Int(digits.parse()?))
                }
                other => Err(anyhow!("bad i64 encoding {other}")),
            },
            "s" => Ok(ParamValue::Str(
                val.as_str().ok_or_else(|| anyhow!("bad str encoding {val}"))?.to_string(),
            )),
            other => Err(anyhow!("unknown param value tag '{other}'")),
        }
    }
}

impl fmt::Display for ParamValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ParamValue::F64(v) => write!(f, "{v:.6}"),
            ParamValue::Int(v) => write!(f, "{v}"),
            ParamValue::Str(s) => write!(f, "{s}"),
        }
    }
}

/// A full hyperparameter configuration: ordered (name, value) pairs.
///
/// Order follows the search-space definition, so encoding and display are
/// deterministic. Lookup is by name (spaces are small: <= dozens of params).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Config {
    entries: Vec<(String, ParamValue)>,
}

impl Config {
    pub fn new(entries: Vec<(String, ParamValue)>) -> Self {
        Self { entries }
    }

    pub fn entries(&self) -> &[(String, ParamValue)] {
        &self.entries
    }

    pub fn get(&self, name: &str) -> Option<&ParamValue> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, v)| v)
    }

    pub fn get_f64(&self, name: &str) -> Option<f64> {
        self.get(name).and_then(|v| v.as_f64())
    }

    pub fn get_i64(&self, name: &str) -> Option<i64> {
        self.get(name).and_then(|v| v.as_i64())
    }

    pub fn get_str(&self, name: &str) -> Option<&str> {
        self.get(name).and_then(|v| v.as_str())
    }

    pub fn set(&mut self, name: &str, value: ParamValue) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == name) {
            e.1 = value;
        } else {
            self.entries.push((name.to_string(), value));
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let map: BTreeMap<String, Json> =
            self.entries.iter().map(|(n, v)| (n.clone(), v.to_json())).collect();
        Json::Obj(map)
    }

    /// Canonical journal encoding: an array of `[name, value]` pairs.
    /// Unlike [`to_json`](Self::to_json) (a `BTreeMap`-backed object that
    /// re-sorts keys), the array preserves entry order, so the encoding of
    /// a given `Config` is unique and replay reconstructs it exactly.
    pub fn to_journal_json(&self) -> Json {
        Json::Arr(
            self.entries
                .iter()
                .map(|(n, v)| Json::Arr(vec![Json::Str(n.clone()), v.to_journal_json()]))
                .collect(),
        )
    }

    /// Decode [`to_journal_json`](Self::to_journal_json)'s encoding.
    pub fn from_journal_json(j: &Json) -> Result<Self> {
        let pairs = j.as_arr().ok_or_else(|| anyhow!("config must be an array, found {j}"))?;
        let mut entries = Vec::with_capacity(pairs.len());
        for p in pairs {
            let pair = p
                .as_arr()
                .filter(|a| a.len() == 2)
                .ok_or_else(|| anyhow!("config entry must be a [name, value] pair"))?;
            let name = pair[0]
                .as_str()
                .ok_or_else(|| anyhow!("config entry name must be a string"))?;
            entries.push((name.to_string(), ParamValue::from_journal_json(&pair[1])?));
        }
        Ok(Self { entries })
    }
}

impl fmt::Display for Config {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{")?;
        for (i, (n, v)) in self.entries.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{n}: {v}")?;
        }
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_access() {
        let c = Config::new(vec![
            ("lr".into(), ParamValue::F64(0.1)),
            ("depth".into(), ParamValue::Int(5)),
            ("booster".into(), ParamValue::Str("dart".into())),
        ]);
        assert_eq!(c.get_f64("lr"), Some(0.1));
        assert_eq!(c.get_f64("depth"), Some(5.0)); // int coerces to f64
        assert_eq!(c.get_i64("depth"), Some(5));
        assert_eq!(c.get_str("booster"), Some("dart"));
        assert_eq!(c.get("missing"), None);
    }

    #[test]
    fn set_overwrites_or_appends() {
        let mut c = Config::default();
        c.set("a", ParamValue::Int(1));
        c.set("a", ParamValue::Int(2));
        assert_eq!(c.get_i64("a"), Some(2));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn json_and_display() {
        let c = Config::new(vec![
            ("x".into(), ParamValue::F64(1.5)),
            ("kind".into(), ParamValue::Str("rbf".into())),
        ]);
        assert_eq!(c.to_json().to_string(), r#"{"kind":"rbf","x":1.5}"#);
        assert_eq!(c.to_string(), "{x: 1.500000, kind: rbf}");
    }

    // ---------------- canonical journal codec ----------------

    /// serialize → parse → re-serialize must be bit-identical (value bits
    /// AND serialized text) for the full f64 range.
    fn roundtrip_f64(v: f64) {
        let text = f64_to_json(v).to_string();
        let parsed = f64_from_json(&crate::config::json::parse(&text).unwrap()).unwrap();
        assert_eq!(
            parsed.to_bits(),
            v.to_bits(),
            "f64 bits changed: {v:?} ({:016x}) -> {parsed:?} via {text}",
            v.to_bits()
        );
        assert_eq!(f64_to_json(parsed).to_string(), text, "re-serialization differs");
    }

    #[test]
    fn f64_codec_exact_on_special_values() {
        for v in [
            0.0,
            -0.0,
            1.5,
            -3.25,
            1e-300,
            f64::MIN_POSITIVE / 8.0, // subnormal
            1e300,
            1e15,
            2.5e15,
            (1u64 << 53) as f64,
            f64::MAX,
            f64::MIN,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
            -f64::NAN,
            f64::from_bits(0x7ff8_0000_0000_0001), // NaN with a payload
            f64::from_bits(0xfff0_dead_beef_0001), // negative signaling-ish NaN
        ] {
            roundtrip_f64(v);
        }
    }

    #[test]
    fn f64_codec_exact_on_arbitrary_bit_patterns() {
        crate::util::proptest::check("f64 journal codec is bit-exact", 512, |g| {
            let v = f64::from_bits(g.rng().next_u64());
            let text = f64_to_json(v).to_string();
            let parsed =
                f64_from_json(&crate::config::json::parse(&text).map_err(|e| e.to_string())?)
                    .map_err(|e| e.to_string())?;
            if parsed.to_bits() != v.to_bits() {
                return Err(format!("{:016x} -> {:016x} via {text}", v.to_bits(), parsed.to_bits()));
            }
            Ok(())
        });
    }

    #[test]
    fn param_value_journal_roundtrip() {
        crate::util::proptest::check("param value journal codec", 256, |g| {
            let v = match g.usize_range(0, 4) {
                0 => ParamValue::F64(f64::from_bits(g.rng().next_u64())),
                1 => ParamValue::F64(g.f64_range(-1e6, 1e6)),
                2 => ParamValue::Int(g.rng().next_u64() as i64),
                _ => ParamValue::Str(format!("choice_{}", g.usize_range(0, 1000))),
            };
            let text = v.to_journal_json().to_string();
            let parsed = ParamValue::from_journal_json(
                &crate::config::json::parse(&text).map_err(|e| e.to_string())?,
            )
            .map_err(|e| e.to_string())?;
            // Bit-level equality (PartialEq would treat NaN != NaN).
            let same = match (&v, &parsed) {
                (ParamValue::F64(a), ParamValue::F64(b)) => a.to_bits() == b.to_bits(),
                (a, b) => a == b,
            };
            if !same {
                return Err(format!("{v:?} -> {parsed:?} via {text}"));
            }
            if parsed.to_journal_json().to_string() != text {
                return Err(format!("re-serialization of {text} differs"));
            }
            Ok(())
        });
    }

    #[test]
    fn config_journal_roundtrip_preserves_order_and_bits() {
        // Entry order is load-bearing (encoding, GP features): the codec
        // must preserve it even where to_json()'s BTreeMap re-sorts.
        let c = Config::new(vec![
            ("z_last".into(), ParamValue::F64(f64::NAN)),
            ("a_first".into(), ParamValue::F64(-0.0)),
            ("big".into(), ParamValue::Int(i64::MAX)),
            ("booster".into(), ParamValue::Str("dart".into())),
            ("q".into(), ParamValue::F64(0.75)),
        ]);
        let text = c.to_journal_json().to_string();
        let parsed =
            Config::from_journal_json(&crate::config::json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed.entries().len(), 5);
        for ((n1, v1), (n2, v2)) in c.entries().iter().zip(parsed.entries()) {
            assert_eq!(n1, n2, "entry order must survive");
            match (v1, v2) {
                (ParamValue::F64(a), ParamValue::F64(b)) => assert_eq!(a.to_bits(), b.to_bits()),
                (a, b) => assert_eq!(a, b),
            }
        }
        assert_eq!(parsed.to_journal_json().to_string(), text);
        assert_eq!(parsed.get_i64("big"), Some(i64::MAX), "i64::MAX survives exactly");
    }

    #[test]
    fn journal_codec_rejects_malformed_input() {
        for bad in [
            r#"{"f":1.0,"i":2}"#, // two tags
            r#"{"x":1.0}"#,       // unknown tag
            r#"{"f":"g64:0000000000000000"}"#,
            r#"{"f":"f64:xyz"}"#,
            r#"{"i":"i64:notanumber"}"#,
            r#"{"i":2.5}"#,
            r#"{"i":1e300}"#,
            r#"{"s":3}"#,
            r#"[1,2]"#,
        ] {
            let j = crate::config::json::parse(bad).unwrap();
            assert!(ParamValue::from_journal_json(&j).is_err(), "accepted {bad}");
        }
        for bad in [r#"{"a":1}"#, r#"[["x"]]"#, r#"[[1,{"f":0}]]"#] {
            let j = crate::config::json::parse(bad).unwrap();
            assert!(Config::from_journal_json(&j).is_err(), "accepted {bad}");
        }
    }
}
