//! Parameter values and configurations (the `params` dicts of the paper).

use crate::config::json::Json;
use std::collections::BTreeMap;
use std::fmt;

/// One hyperparameter value.
#[derive(Clone, Debug, PartialEq)]
pub enum ParamValue {
    F64(f64),
    Int(i64),
    Str(String),
}

impl ParamValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            ParamValue::F64(v) => Some(*v),
            ParamValue::Int(v) => Some(*v as f64),
            ParamValue::Str(_) => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            ParamValue::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            ParamValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            ParamValue::F64(v) => Json::Num(*v),
            ParamValue::Int(v) => Json::Num(*v as f64),
            ParamValue::Str(s) => Json::Str(s.clone()),
        }
    }
}

impl fmt::Display for ParamValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ParamValue::F64(v) => write!(f, "{v:.6}"),
            ParamValue::Int(v) => write!(f, "{v}"),
            ParamValue::Str(s) => write!(f, "{s}"),
        }
    }
}

/// A full hyperparameter configuration: ordered (name, value) pairs.
///
/// Order follows the search-space definition, so encoding and display are
/// deterministic. Lookup is by name (spaces are small: <= dozens of params).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct Config {
    entries: Vec<(String, ParamValue)>,
}

impl Config {
    pub fn new(entries: Vec<(String, ParamValue)>) -> Self {
        Self { entries }
    }

    pub fn entries(&self) -> &[(String, ParamValue)] {
        &self.entries
    }

    pub fn get(&self, name: &str) -> Option<&ParamValue> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, v)| v)
    }

    pub fn get_f64(&self, name: &str) -> Option<f64> {
        self.get(name).and_then(|v| v.as_f64())
    }

    pub fn get_i64(&self, name: &str) -> Option<i64> {
        self.get(name).and_then(|v| v.as_i64())
    }

    pub fn get_str(&self, name: &str) -> Option<&str> {
        self.get(name).and_then(|v| v.as_str())
    }

    pub fn set(&mut self, name: &str, value: ParamValue) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == name) {
            e.1 = value;
        } else {
            self.entries.push((name.to_string(), value));
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let map: BTreeMap<String, Json> =
            self.entries.iter().map(|(n, v)| (n.clone(), v.to_json())).collect();
        Json::Obj(map)
    }
}

impl fmt::Display for Config {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{")?;
        for (i, (n, v)) in self.entries.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{n}: {v}")?;
        }
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_access() {
        let c = Config::new(vec![
            ("lr".into(), ParamValue::F64(0.1)),
            ("depth".into(), ParamValue::Int(5)),
            ("booster".into(), ParamValue::Str("dart".into())),
        ]);
        assert_eq!(c.get_f64("lr"), Some(0.1));
        assert_eq!(c.get_f64("depth"), Some(5.0)); // int coerces to f64
        assert_eq!(c.get_i64("depth"), Some(5));
        assert_eq!(c.get_str("booster"), Some("dart"));
        assert_eq!(c.get("missing"), None);
    }

    #[test]
    fn set_overwrites_or_appends() {
        let mut c = Config::default();
        c.set("a", ParamValue::Int(1));
        c.set("a", ParamValue::Int(2));
        assert_eq!(c.get_i64("a"), Some(2));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn json_and_display() {
        let c = Config::new(vec![
            ("x".into(), ParamValue::F64(1.5)),
            ("kind".into(), ParamValue::Str("rbf".into())),
        ]);
        assert_eq!(c.to_json().to_string(), r#"{"kind":"rbf","x":1.5}"#);
        assert_eq!(c.to_string(), "{x: 1.500000, kind: rbf}");
    }
}
