//! Columnar Monte-Carlo candidate generation: batch sampling straight into
//! typed structure-of-arrays columns plus the encoded GP feature matrix,
//! with **zero per-candidate `Config` materialization**.
//!
//! The legacy path ([`SearchSpace::sample_n`] → `Encoder::encode_batch`)
//! allocates one `Config` per candidate — a `Vec<(String, ParamValue)>`
//! with every parameter name cloned — before re-walking each config to
//! encode it. At the m ≥ 10⁵ candidate counts the acquisition wants
//! (paper §2.3: candidate-set size is the batch-quality lever), that is
//! O(m·p) `String`/heap churn dominating the propose step.
//! [`SearchSpace::sample_columnar`] instead draws each value through the
//! same [`super::Draw`]-typed path `Domain::sample` uses — **the exact
//! config-major, param-order RNG sequence**, so every sampled value is
//! bit-identical to the legacy stream — and writes it twice: once into its
//! param's typed column (`f64`/`i64`/choice-index vectors) and once,
//! through the shared [`super::encode::encode_numeric`] arithmetic, into
//! the m×d encoded matrix. Only the ≤ batch-size argmax winners are ever
//! materialized into `Config`s ([`ColumnarSet::config`]).

use super::encode::encode_numeric;
use super::{Config, Domain, Draw, ParamValue, SearchSpace};
use crate::linalg::Matrix;
use crate::util::rng::Pcg64;

/// One parameter's sampled values across the whole candidate set, in the
/// parameter's native machine type.
#[derive(Clone, Debug, PartialEq)]
pub enum ColumnData {
    /// Continuous domains (uniform, loguniform, quniform, normal, custom).
    F64(Vec<f64>),
    /// Integer `Range` domains.
    I64(Vec<i64>),
    /// `Choice` domains: the sampled index into the domain's value list.
    Choice(Vec<u32>),
}

impl ColumnData {
    fn with_capacity(domain: &Domain, m: usize) -> Self {
        match domain {
            Domain::Range { .. } => ColumnData::I64(Vec::with_capacity(m)),
            Domain::Choice(_) => ColumnData::Choice(Vec::with_capacity(m)),
            _ => ColumnData::F64(Vec::with_capacity(m)),
        }
    }

    /// Number of sampled values in this column.
    pub fn len(&self) -> usize {
        match self {
            ColumnData::F64(v) => v.len(),
            ColumnData::I64(v) => v.len(),
            ColumnData::Choice(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A columnar candidate set: typed per-parameter SoA columns plus the
/// encoded (m × d) feature matrix, produced by
/// [`SearchSpace::sample_columnar`]. Candidates exist only as column
/// entries until a caller materializes a specific row via
/// [`config`](Self::config).
#[derive(Clone, Debug)]
pub struct ColumnarSet {
    space: SearchSpace,
    m: usize,
    dims: usize,
    /// One column per parameter, in space order.
    columns: Vec<ColumnData>,
    /// Row-major m × dims encoded features; empty after
    /// [`take_encoded_matrix`](Self::take_encoded_matrix) moves it out.
    encoded: Vec<f64>,
}

impl ColumnarSet {
    /// Number of candidates.
    pub fn len(&self) -> usize {
        self.m
    }

    pub fn is_empty(&self) -> bool {
        self.m == 0
    }

    /// Encoded feature width.
    pub fn dims(&self) -> usize {
        self.dims
    }

    /// The raw encoded buffer (row-major m × dims); empty once
    /// [`take_encoded_matrix`](Self::take_encoded_matrix) has moved it out.
    pub fn encoded(&self) -> &[f64] {
        &self.encoded
    }

    /// Move the encoded buffer out as an (m × dims) matrix without
    /// copying. The columns (and [`config`](Self::config)) stay usable;
    /// [`encoded`](Self::encoded) is empty afterwards.
    pub fn take_encoded_matrix(&mut self) -> Matrix {
        Matrix::from_vec(self.m, self.dims, std::mem::take(&mut self.encoded))
    }

    /// One parameter's sampled column (space order).
    pub fn column(&self, param: usize) -> &ColumnData {
        &self.columns[param]
    }

    /// Materialize candidate `i` as a full [`Config`] — called only for
    /// the argmax winners, never for the whole set. The produced config is
    /// bit-identical to what the legacy `sample_n` path would have built
    /// for the same draw.
    pub fn config(&self, i: usize) -> Config {
        assert!(i < self.m, "candidate index {i} out of range (m = {})", self.m);
        let mut entries = Vec::with_capacity(self.space.len());
        for (p, col) in self.space.params().iter().zip(&self.columns) {
            let v = match col {
                ColumnData::F64(vals) => ParamValue::F64(vals[i]),
                ColumnData::I64(vals) => ParamValue::Int(vals[i]),
                ColumnData::Choice(idxs) => match &p.domain {
                    Domain::Choice(vals) => vals[idxs[i] as usize].clone(),
                    other => unreachable!("choice column on non-choice domain {other:?}"),
                },
            };
            entries.push((p.name.clone(), v));
        }
        Config::new(entries)
    }

    /// Materialize every candidate (cold-start helpers that need a whole
    /// small batch of `Config`s; the hot path never calls this).
    pub fn into_configs(self) -> Vec<Config> {
        (0..self.m).map(|i| self.config(i)).collect()
    }
}

impl SearchSpace {
    /// Sample `m` candidates straight into columnar form: typed SoA
    /// columns plus the encoded (m × d) matrix, no per-candidate `Config`.
    ///
    /// Draws in the exact config-major, param-order RNG sequence of the
    /// legacy [`sample_n`](Self::sample_n), through the same
    /// [`Domain::sample_draw`] implementation, and encodes through the
    /// same [`encode_numeric`] arithmetic as `Encoder::encode_into` — so
    /// sampled values, encoded features, and the post-call RNG state are
    /// all bit-identical to the legacy path (property-tested).
    pub fn sample_columnar(&self, rng: &mut Pcg64, m: usize) -> ColumnarSet {
        let params = self.params();
        // Per-param encoded offsets, plus the canonical one-hot slot per
        // choice index: `encode_into` one-hots the *first* position whose
        // value equals the sampled one, so duplicate choice values must
        // collapse to the same slot here too.
        let mut offsets = Vec::with_capacity(params.len());
        let mut canon: Vec<Vec<usize>> = Vec::with_capacity(params.len());
        let mut dims = 0usize;
        for p in params {
            offsets.push(dims);
            dims += p.domain.encoded_width();
            canon.push(match &p.domain {
                Domain::Choice(vals) => vals
                    .iter()
                    .map(|v| vals.iter().position(|c| c == v).expect("value finds itself"))
                    .collect(),
                _ => Vec::new(),
            });
        }

        let mut columns: Vec<ColumnData> =
            params.iter().map(|p| ColumnData::with_capacity(&p.domain, m)).collect();
        let mut encoded = vec![0.0; m * dims];
        for i in 0..m {
            let row = &mut encoded[i * dims..(i + 1) * dims];
            for (j, p) in params.iter().enumerate() {
                let off = offsets[j];
                match (p.domain.sample_draw(rng), &mut columns[j]) {
                    (Draw::F64(x), ColumnData::F64(col)) => {
                        col.push(x);
                        row[off] = encode_numeric(&p.domain, x);
                    }
                    (Draw::Int(v), ColumnData::I64(col)) => {
                        col.push(v);
                        row[off] = encode_numeric(&p.domain, v as f64);
                    }
                    (Draw::Choice(idx), ColumnData::Choice(col)) => {
                        col.push(idx as u32);
                        row[off + canon[j][idx]] = 1.0;
                    }
                    (draw, col) => {
                        unreachable!("draw {draw:?} does not match column {col:?}")
                    }
                }
            }
        }
        ColumnarSet { space: self.clone(), m, dims, columns, encoded }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::dist::{Beta, TruncExp};
    use crate::space::{xgboost_space, Encoder, SearchSpaceBuilder};
    use crate::util::proptest::{check, Gen};
    use std::sync::Arc;

    /// Bit-level equality for ParamValues (PartialEq collapses -0.0 == 0.0
    /// and fails on NaN; the contract here is *bit* identity).
    fn bits_eq(a: &ParamValue, b: &ParamValue) -> bool {
        match (a, b) {
            (ParamValue::F64(x), ParamValue::F64(y)) => x.to_bits() == y.to_bits(),
            (x, y) => x == y,
        }
    }

    /// A random space covering every domain kind, incl. `Custom` and
    /// `Choice` (with value-type variety and possible duplicate values).
    fn arbitrary_space(g: &mut Gen) -> SearchSpace {
        let n_params = g.usize_range(1, 7);
        let mut b = SearchSpaceBuilder::default();
        for i in 0..n_params {
            let name = format!("p{i}");
            b = match g.usize_range(0, 8) {
                0 => {
                    let lo = g.f64_range(-10.0, 10.0);
                    b.uniform(&name, lo, lo + g.f64_range(0.1, 20.0))
                }
                1 => {
                    let lo = g.f64_range(1e-6, 1.0);
                    b.loguniform(&name, lo, lo * g.f64_range(2.0, 1e6))
                }
                2 => {
                    let lo = g.f64_range(-5.0, 5.0);
                    b.quniform(&name, lo, lo + g.f64_range(0.5, 10.0), g.f64_range(0.01, 0.5))
                }
                3 => b.normal(&name, g.f64_range(-3.0, 3.0), g.f64_range(0.1, 2.0)),
                4 => {
                    let lo = g.f64_range(-50.0, 50.0) as i64;
                    b.int(&name, lo, lo + g.usize_range(0, 30) as i64)
                }
                5 => {
                    // Choice over mixed value types, duplicates possible.
                    let k = g.usize_range(1, 6);
                    let vals: Vec<ParamValue> = (0..k)
                        .map(|_| match g.usize_range(0, 3) {
                            0 => ParamValue::Str(format!("v{}", g.usize_range(0, 3))),
                            1 => ParamValue::Int(g.usize_range(0, 4) as i64),
                            _ => ParamValue::F64(g.f64_range(-2.0, 2.0)),
                        })
                        .collect();
                    b.choice_values(&name, vals)
                }
                6 => b.custom(
                    &name,
                    Arc::new(TruncExp { rate: g.f64_range(0.5, 4.0), hi: g.f64_range(1.0, 5.0) }),
                ),
                _ => b.custom(
                    &name,
                    Arc::new(Beta { a: g.f64_range(0.5, 4.0), b: g.f64_range(0.5, 4.0) }),
                ),
            };
        }
        b.build()
    }

    /// The tentpole contract: over arbitrary spaces (every domain kind,
    /// incl. `Custom` and `Choice`) and seeds, `sample_columnar` draws
    /// values bit-identical to the legacy `sample_n` stream, encodes
    /// bit-identically to `Encoder::encode_batch`, and leaves the RNG in
    /// the identical state.
    #[test]
    fn property_sample_columnar_is_bit_identical_to_legacy_sample_n() {
        check("sample_columnar == sample_n", 96, |g| {
            let space = arbitrary_space(g);
            let m = g.usize_range(0, 24);
            let seed = g.rng().next_u64();

            let mut legacy_rng = Pcg64::new(seed);
            let legacy = space.sample_n(&mut legacy_rng, m);
            let enc = Encoder::new(&space);
            let legacy_encoded = enc.encode_batch(&legacy);

            let mut col_rng = Pcg64::new(seed);
            let set = space.sample_columnar(&mut col_rng, m);

            if col_rng.state() != legacy_rng.state() {
                return Err("RNG streams diverged".into());
            }
            if set.len() != m || set.dims() != enc.dims() {
                return Err(format!("shape: m={} dims={}", set.len(), set.dims()));
            }
            if set.encoded().len() != legacy_encoded.len()
                || set
                    .encoded()
                    .iter()
                    .zip(&legacy_encoded)
                    .any(|(a, b)| a.to_bits() != b.to_bits())
            {
                return Err("encoded features deviate from encode_batch".into());
            }
            for (i, want) in legacy.iter().enumerate() {
                let got = set.config(i);
                if got.len() != want.len()
                    || got
                        .entries()
                        .iter()
                        .zip(want.entries())
                        .any(|((n1, v1), (n2, v2))| n1 != n2 || !bits_eq(v1, v2))
                {
                    return Err(format!("candidate {i}: {got} != {want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn take_encoded_matrix_moves_the_buffer_out() {
        let space = xgboost_space();
        let mut rng = Pcg64::new(7);
        let mut set = space.sample_columnar(&mut rng, 10);
        let enc = Encoder::new(&space);
        let legacy = enc.encode_batch(&space.sample_n(&mut Pcg64::new(7), 10));
        let xc = set.take_encoded_matrix();
        assert_eq!(xc.rows(), 10);
        assert_eq!(xc.cols(), 7);
        for i in 0..10 {
            assert_eq!(xc.row(i), &legacy[i * 7..(i + 1) * 7]);
        }
        assert!(set.encoded().is_empty(), "the buffer must be moved, not copied");
        // Columns stay usable for winner materialization after the take.
        let c = set.config(3);
        assert_eq!(c.len(), 5);
    }

    #[test]
    fn into_configs_matches_sample_n() {
        let space = xgboost_space();
        let a = space.sample_columnar(&mut Pcg64::new(31), 8).into_configs();
        let b = space.sample_n(&mut Pcg64::new(31), 8);
        assert_eq!(a, b);
    }

    #[test]
    fn duplicate_choice_values_one_hot_the_canonical_slot() {
        // Choice ["a", "b", "a"]: sampling index 2 must one-hot slot 0 —
        // exactly where encode_into's position() lookup lands for "a".
        let space = SearchSpaceBuilder::default()
            .choice("dup", &["a", "b", "a"])
            .build();
        let enc = Encoder::new(&space);
        let mut rng = Pcg64::new(0);
        // Draw until both "a" slots have been sampled at least once.
        let set = space.sample_columnar(&mut rng, 64);
        let ColumnData::Choice(idxs) = set.column(0) else { panic!("choice column") };
        assert!(idxs.iter().any(|&i| i == 2), "index 2 must occur in 64 draws");
        for (i, &idx) in idxs.iter().enumerate() {
            let row = &set.encoded()[i * 3..(i + 1) * 3];
            let expect = enc.encode(&set.config(i));
            assert_eq!(row, expect.as_slice(), "candidate {i} (drew index {idx})");
            if idx == 2 {
                assert_eq!(row, &[1.0, 0.0, 0.0], "duplicate collapses to slot 0");
            }
        }
    }

    #[test]
    fn empty_set_is_well_formed() {
        let space = xgboost_space();
        let mut set = space.sample_columnar(&mut Pcg64::new(1), 0);
        assert!(set.is_empty());
        assert_eq!(set.take_encoded_matrix().rows(), 0);
        assert!(set.into_configs().is_empty());
    }
}
