//! GP feature encoding: configurations → unit-cube vectors.
//!
//! Continuous domains min-max scale to [0, 1] (loguniform in log space,
//! normals over mean ± 3σ); integers scale like continuous; categoricals
//! one-hot encode. This is the Garrido-Merchán & Hernández-Lobato treatment
//! the paper cites: the acquisition is only ever *evaluated at valid
//! configurations* (we sample configs, then encode), so the GP never sees
//! fractional categories.

use super::{Config, Domain, SearchSpace};

/// Encode one numeric (non-choice) domain value into its unit-cube GP
/// feature. The **single copy** of the per-domain scaling arithmetic,
/// shared by [`Encoder::encode_into`] and the columnar sampler
/// ([`super::columnar`]) — both paths produce bit-identical features
/// because they run this exact function.
///
/// Panics on `Choice` domains (they one-hot encode, there is no scalar).
pub(crate) fn encode_numeric(domain: &Domain, x: f64) -> f64 {
    match domain {
        Domain::Uniform { lo, hi } | Domain::QUniform { lo, hi, .. } => {
            ((x - lo) / (hi - lo)).clamp(0.0, 1.0)
        }
        Domain::LogUniform { lo, hi } => {
            let x = x.max(*lo);
            ((x.ln() - lo.ln()) / (hi.ln() - lo.ln())).clamp(0.0, 1.0)
        }
        Domain::Normal { mean, std } => ((x - (mean - 3.0 * std)) / (6.0 * std)).clamp(0.0, 1.0),
        Domain::Range { lo, hi } => {
            let span = (*hi - *lo).max(1) as f64;
            ((x - *lo as f64) / span).clamp(0.0, 1.0)
        }
        Domain::Custom(d) => {
            let (lo, hi) = d.bounds();
            ((x - lo) / (hi - lo)).clamp(0.0, 1.0)
        }
        Domain::Choice(_) => unreachable!("choice domains one-hot encode"),
    }
}

/// Precomputed encoding layout for a [`SearchSpace`].
#[derive(Clone, Debug)]
pub struct Encoder {
    dims: usize,
    /// Per-parameter (offset, width) into the encoded vector.
    layout: Vec<(usize, usize)>,
    space: SearchSpace,
}

impl Encoder {
    pub fn new(space: &SearchSpace) -> Self {
        let mut layout = Vec::with_capacity(space.len());
        let mut off = 0;
        for p in space.params() {
            let w = p.domain.encoded_width();
            layout.push((off, w));
            off += w;
        }
        Self { dims: off, layout, space: space.clone() }
    }

    /// Number of encoded feature dimensions.
    pub fn dims(&self) -> usize {
        self.dims
    }

    /// Encode one configuration into `out[..self.dims()]`.
    pub fn encode_into(&self, cfg: &Config, out: &mut [f64]) {
        assert!(out.len() >= self.dims);
        out[..self.dims].fill(0.0);
        for (p, &(off, width)) in self.space.params().iter().zip(&self.layout) {
            let v = cfg
                .get(&p.name)
                .unwrap_or_else(|| panic!("config missing parameter '{}'", p.name));
            match &p.domain {
                Domain::Choice(vals) => {
                    let idx = vals
                        .iter()
                        .position(|c| c == v)
                        .unwrap_or_else(|| panic!("'{v}' not a valid choice for '{}'", p.name));
                    out[off + idx] = 1.0;
                    let _ = width;
                }
                domain => {
                    out[off] = encode_numeric(domain, v.as_f64().expect("numeric param"));
                }
            }
        }
    }

    /// Encode one configuration (allocating).
    pub fn encode(&self, cfg: &Config) -> Vec<f64> {
        let mut out = vec![0.0; self.dims];
        self.encode_into(cfg, &mut out);
        out
    }

    /// Encode a batch into a flat row-major (n x dims) buffer.
    pub fn encode_batch(&self, cfgs: &[Config]) -> Vec<f64> {
        let mut out = vec![0.0; cfgs.len() * self.dims];
        for (i, cfg) in cfgs.iter().enumerate() {
            self.encode_into(cfg, &mut out[i * self.dims..(i + 1) * self.dims]);
        }
        out
    }

    /// Euclidean distance in encoded space (used by the k-means batcher).
    pub fn encoded_distance(&self, a: &Config, b: &Config) -> f64 {
        let ea = self.encode(a);
        let eb = self.encode(b);
        ea.iter().zip(&eb).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::space::{xgboost_space, ParamValue, SearchSpace};
    use crate::util::proptest::check;
    use crate::util::rng::Pcg64;

    #[test]
    fn xgboost_layout() {
        let s = xgboost_space();
        let enc = Encoder::new(&s);
        assert_eq!(enc.dims(), 7);
        let mut rng = Pcg64::new(1);
        let cfg = s.sample(&mut rng);
        let v = enc.encode(&cfg);
        assert_eq!(v.len(), 7);
        // one-hot block sums to exactly 1
        let onehot_sum: f64 = v[4..7].iter().sum();
        assert!((onehot_sum - 1.0).abs() < 1e-12);
        assert_eq!(v[4..7].iter().filter(|&&x| x == 1.0).count(), 1);
    }

    #[test]
    fn encoded_values_in_unit_cube_property() {
        let s = xgboost_space();
        let enc = Encoder::new(&s);
        check("encodings in [0,1]", 256, |g| {
            let cfg = s.sample(g.rng());
            let v = enc.encode(&cfg);
            for (i, x) in v.iter().enumerate() {
                if !(0.0..=1.0).contains(x) {
                    return Err(format!("dim {i} = {x}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn loguniform_encodes_log_linearly() {
        let s = SearchSpace::builder().loguniform("g", 1e-4, 1e4).build();
        let enc = Encoder::new(&s);
        let at = |x: f64| {
            enc.encode(&Config::new(vec![("g".into(), ParamValue::F64(x))]))[0]
        };
        assert!((at(1e-4) - 0.0).abs() < 1e-9);
        assert!((at(1.0) - 0.5).abs() < 1e-9);
        assert!((at(1e4) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn range_endpoints() {
        let s = SearchSpace::builder().range("d", 1, 10).build(); // values 1..=9
        let enc = Encoder::new(&s);
        let at = |x: i64| {
            enc.encode(&Config::new(vec![("d".into(), ParamValue::Int(x))]))[0]
        };
        assert_eq!(at(1), 0.0);
        assert_eq!(at(9), 1.0);
        assert!((at(5) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn batch_encoding_matches_single() {
        let s = xgboost_space();
        let enc = Encoder::new(&s);
        let mut rng = Pcg64::new(3);
        let cfgs = s.sample_n(&mut rng, 5);
        let batch = enc.encode_batch(&cfgs);
        for (i, cfg) in cfgs.iter().enumerate() {
            assert_eq!(&batch[i * 7..(i + 1) * 7], enc.encode(cfg).as_slice());
        }
    }

    #[test]
    #[should_panic(expected = "missing parameter")]
    fn missing_param_panics() {
        let s = xgboost_space();
        let enc = Encoder::new(&s);
        enc.encode(&Config::default());
    }

    #[test]
    fn distance_zero_iff_same() {
        let s = xgboost_space();
        let enc = Encoder::new(&s);
        let mut rng = Pcg64::new(7);
        let a = s.sample(&mut rng);
        let b = s.sample(&mut rng);
        assert_eq!(enc.encoded_distance(&a, &a), 0.0);
        assert!(enc.encoded_distance(&a, &b) > 0.0);
    }
}
