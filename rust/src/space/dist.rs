//! Custom-distribution extension point (the analogue of defining new
//! scipy.stats distributions in the paper).

use crate::util::rng::Pcg64;

/// A user-defined continuous distribution.
///
/// Implementors must provide `sample` (the paper: "Distributions must
/// provide a method for sampling") and finite `bounds` used for GP encoding.
pub trait Distribution: Send + Sync {
    /// Draw one value.
    fn sample(&self, rng: &mut Pcg64) -> f64;

    /// (lo, hi) support bounds used to scale values into the GP unit cube.
    fn bounds(&self) -> (f64, f64);

    /// Human-readable name for Debug output.
    fn name(&self) -> &str {
        "custom"
    }
}

/// Truncated exponential — ships as a worked example of the extension point
/// (the paper ships `loguniform` as its example; we ship both).
pub struct TruncExp {
    pub rate: f64,
    pub hi: f64,
}

impl Distribution for TruncExp {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        // Inverse-CDF of Exp(rate) truncated to [0, hi].
        let cdf_hi = 1.0 - (-self.rate * self.hi).exp();
        let u = rng.next_f64() * cdf_hi;
        -(1.0 - u).ln() / self.rate
    }

    fn bounds(&self) -> (f64, f64) {
        (0.0, self.hi)
    }

    fn name(&self) -> &str {
        "truncexp"
    }
}

/// Beta(a, b) via the Jöhnk/gamma-ratio method — a second worked example,
/// covering bounded asymmetric priors.
pub struct Beta {
    pub a: f64,
    pub b: f64,
}

impl Beta {
    fn gamma_sample(shape: f64, rng: &mut Pcg64) -> f64 {
        // Marsaglia–Tsang for shape >= 1; boost for shape < 1.
        if shape < 1.0 {
            let u = rng.next_f64().max(1e-300);
            return Self::gamma_sample(shape + 1.0, rng) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = rng.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = rng.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4) || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }
}

impl Distribution for Beta {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        let x = Self::gamma_sample(self.a, rng);
        let y = Self::gamma_sample(self.b, rng);
        x / (x + y)
    }

    fn bounds(&self) -> (f64, f64) {
        (0.0, 1.0)
    }

    fn name(&self) -> &str {
        "beta"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn truncexp_in_bounds() {
        let d = TruncExp { rate: 2.0, hi: 3.0 };
        let mut rng = Pcg64::new(4);
        for _ in 0..2000 {
            let v = d.sample(&mut rng);
            assert!((0.0..=3.0).contains(&v), "{v}");
        }
    }

    #[test]
    fn truncexp_mean_close_to_untruncated() {
        // rate=2, hi=3: truncation is mild; mean should be near 1/2.
        let d = TruncExp { rate: 2.0, hi: 3.0 };
        let mut rng = Pcg64::new(5);
        let n = 20_000;
        let m: f64 = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
        assert!((m - 0.49).abs() < 0.03, "mean {m}");
    }

    #[test]
    fn beta_moments() {
        let d = Beta { a: 2.0, b: 5.0 };
        let mut rng = Pcg64::new(6);
        let n = 20_000;
        let samples: Vec<f64> = (0..n).map(|_| d.sample(&mut rng)).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        assert!((mean - 2.0 / 7.0).abs() < 0.01, "mean {mean}");
        assert!(samples.iter().all(|v| (0.0..=1.0).contains(v)));
    }
}
