//! Hyperparameter search-space DSL (paper §2.1).
//!
//! A [`SearchSpace`] is an ordered set of named parameters, each with a
//! [`Domain`]: continuous distributions (uniform, loguniform, normal, …),
//! quantized variants, integer ranges (Python's `range(lo, hi)`), and
//! categorical choices (Python lists). Custom distributions plug in through
//! the [`dist::Distribution`] trait — the analogue of extending
//! `scipy.stats` constructs.
//!
//! ```no_run
//! use mango::space::SearchSpace;
//! // Listing 1 of the paper: XGBoost's XGBClassifier space.
//! let space = SearchSpace::builder()
//!     .uniform("learning_rate", 0.0, 1.0)
//!     .uniform("gamma", 0.0, 5.0)
//!     .range("max_depth", 1, 10)
//!     .range("n_estimators", 1, 300)
//!     .choice("booster", &["gbtree", "gblinear", "dart"])
//!     .build();
//! assert_eq!(space.len(), 5);
//! assert!(space.cardinality_estimate() >= 1e6);
//! ```

pub mod columnar;
pub mod dist;
pub mod encode;
mod value;

pub use columnar::{ColumnData, ColumnarSet};
pub use encode::Encoder;
pub use value::{f64_from_json, f64_to_json, Config, ParamValue};

use crate::util::rng::Pcg64;
use dist::Distribution;
use std::sync::Arc;

/// The domain of a single hyperparameter.
#[derive(Clone)]
pub enum Domain {
    /// Continuous uniform on [lo, hi).
    Uniform { lo: f64, hi: f64 },
    /// Log-uniform on [lo, hi) (the paper's predefined `loguniform`).
    LogUniform { lo: f64, hi: f64 },
    /// Uniform quantized to multiples of `q`.
    QUniform { lo: f64, hi: f64, q: f64 },
    /// Normal(mean, std), clipped to mean ± 6 std for encoding bounds.
    Normal { mean: f64, std: f64 },
    /// Integer uniform on [lo, hi] inclusive — Python `range(lo, hi)` is
    /// expressed as `Range { lo, hi: hi - 1 }` by the builder.
    Range { lo: i64, hi: i64 },
    /// Categorical over explicit values (strings, numbers, …).
    Choice(Vec<ParamValue>),
    /// User-defined distribution (scipy.stats-style extension point).
    Custom(Arc<dyn Distribution>),
}

impl std::fmt::Debug for Domain {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Domain::Uniform { lo, hi } => write!(f, "Uniform({lo}, {hi})"),
            Domain::LogUniform { lo, hi } => write!(f, "LogUniform({lo}, {hi})"),
            Domain::QUniform { lo, hi, q } => write!(f, "QUniform({lo}, {hi}, q={q})"),
            Domain::Normal { mean, std } => write!(f, "Normal({mean}, {std})"),
            Domain::Range { lo, hi } => write!(f, "Range({lo}..={hi})"),
            Domain::Choice(v) => write!(f, "Choice({} values)", v.len()),
            Domain::Custom(d) => write!(f, "Custom({})", d.name()),
        }
    }
}

/// A freshly drawn value in its native machine type — what
/// [`Domain::sample_draw`] produces before any `ParamValue` boxing. The
/// columnar sampler stores these directly into typed SoA columns; the
/// legacy [`Domain::sample`] wraps them into `ParamValue`s. Both paths
/// share the one RNG-consuming implementation, so they are bit-identical
/// by construction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Draw {
    F64(f64),
    Int(i64),
    /// Index into the domain's `Choice` values.
    Choice(usize),
}

impl Domain {
    /// Draw one value in typed form — the single sampling implementation
    /// every path (legacy `sample`, columnar batch generation) goes
    /// through. One `Draw` consumes exactly the RNG values the legacy
    /// `sample` consumed, in the same order.
    pub fn sample_draw(&self, rng: &mut Pcg64) -> Draw {
        match self {
            Domain::Uniform { lo, hi } => Draw::F64(rng.uniform(*lo, *hi)),
            Domain::LogUniform { lo, hi } => {
                let (ll, lh) = (lo.ln(), hi.ln());
                Draw::F64(rng.uniform(ll, lh).exp())
            }
            Domain::QUniform { lo, hi, q } => {
                let v = rng.uniform(*lo, *hi);
                Draw::F64((v / q).round() * q)
            }
            Domain::Normal { mean, std } => Draw::F64(rng.normal_scaled(*mean, *std)),
            Domain::Range { lo, hi } => {
                Draw::Int(rng.uniform_usize(0, (*hi - *lo + 1) as usize) as i64 + lo)
            }
            Domain::Choice(vals) => Draw::Choice(rng.uniform_usize(0, vals.len())),
            Domain::Custom(d) => Draw::F64(d.sample(rng)),
        }
    }

    /// Draw one value.
    pub fn sample(&self, rng: &mut Pcg64) -> ParamValue {
        match (self.sample_draw(rng), self) {
            (Draw::F64(x), _) => ParamValue::F64(x),
            (Draw::Int(i), _) => ParamValue::Int(i),
            (Draw::Choice(i), Domain::Choice(vals)) => vals[i].clone(),
            (Draw::Choice(_), _) => unreachable!("only Choice domains draw indices"),
        }
    }

    /// How many GP feature dims this domain encodes to (one-hot categoricals).
    pub fn encoded_width(&self) -> usize {
        match self {
            Domain::Choice(vals) => vals.len(),
            _ => 1,
        }
    }

    /// Approximate number of distinct values (for the MC heuristic and the
    /// paper's "cardinality of the search space" discussion).
    pub fn cardinality(&self) -> f64 {
        match self {
            Domain::Uniform { .. }
            | Domain::LogUniform { .. }
            | Domain::Normal { .. }
            | Domain::Custom(_) => 100.0, // continuous: treated as ~100 distinguishable levels
            Domain::QUniform { lo, hi, q } => ((hi - lo) / q).abs().max(1.0),
            Domain::Range { lo, hi } => (hi - lo + 1) as f64,
            Domain::Choice(vals) => vals.len() as f64,
        }
    }

    /// True if values are discrete (integer or categorical).
    pub fn is_discrete(&self) -> bool {
        matches!(self, Domain::Range { .. } | Domain::Choice(_))
    }
}

/// A named parameter.
#[derive(Clone, Debug)]
pub struct Param {
    pub name: String,
    pub domain: Domain,
}

/// Ordered collection of parameters; the library's central abstraction.
#[derive(Clone, Debug, Default)]
pub struct SearchSpace {
    params: Vec<Param>,
}

impl SearchSpace {
    pub fn builder() -> SearchSpaceBuilder {
        SearchSpaceBuilder::default()
    }

    pub fn params(&self) -> &[Param] {
        &self.params
    }

    pub fn len(&self) -> usize {
        self.params.len()
    }

    pub fn is_empty(&self) -> bool {
        self.params.is_empty()
    }

    pub fn param(&self, name: &str) -> Option<&Param> {
        self.params.iter().find(|p| p.name == name)
    }

    /// Sample one full configuration.
    pub fn sample(&self, rng: &mut Pcg64) -> Config {
        Config::new(
            self.params
                .iter()
                .map(|p| (p.name.clone(), p.domain.sample(rng)))
                .collect(),
        )
    }

    /// Sample a batch of configurations, one `Config` per draw.
    ///
    /// This is the *legacy row-major path*, kept as the correctness oracle
    /// for [`SearchSpace::sample_columnar`] (the allocation-free batch
    /// sampler the optimizers use): both draw in the same config-major,
    /// param-order RNG sequence and are property-tested bit-identical.
    pub fn sample_n(&self, rng: &mut Pcg64, n: usize) -> Vec<Config> {
        (0..n).map(|_| self.sample(rng)).collect()
    }

    /// Total GP-encoded feature width.
    pub fn encoded_dim(&self) -> usize {
        self.params.iter().map(|p| p.domain.encoded_width()).sum()
    }

    /// Product of per-parameter cardinalities (paper §1: ~1e6 for Listing 1).
    pub fn cardinality_estimate(&self) -> f64 {
        self.params.iter().map(|p| p.domain.cardinality()).product()
    }

    /// Stable 64-bit fingerprint of the space's structure (names, domain
    /// kinds, and exact bounds/values — floats hashed by IEEE-754 bits).
    /// The run journal records it in its header and `Tuner::resume_from`
    /// refuses to replay a journal against a space with a different
    /// fingerprint: resuming under a changed space would silently re-encode
    /// old configs into different GP features. `Custom` domains hash by
    /// their [`dist::Distribution::name`] — two custom distributions with
    /// the same name are treated as the same domain.
    pub fn fingerprint(&self) -> u64 {
        // FNV-1a (no hashing crates in the offline registry; std's
        // DefaultHasher is explicitly not stable across releases).
        fn eat(h: &mut u64, bytes: &[u8]) {
            for &b in bytes {
                *h ^= b as u64;
                *h = h.wrapping_mul(0x0000_0100_0000_01B3);
            }
        }
        fn eat_f64(h: &mut u64, v: f64) {
            eat(h, &v.to_bits().to_le_bytes());
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for p in &self.params {
            eat(&mut h, p.name.as_bytes());
            eat(&mut h, &[0xFF]); // name/domain separator
            match &p.domain {
                Domain::Uniform { lo, hi } => {
                    eat(&mut h, b"uniform");
                    eat_f64(&mut h, *lo);
                    eat_f64(&mut h, *hi);
                }
                Domain::LogUniform { lo, hi } => {
                    eat(&mut h, b"loguniform");
                    eat_f64(&mut h, *lo);
                    eat_f64(&mut h, *hi);
                }
                Domain::QUniform { lo, hi, q } => {
                    eat(&mut h, b"quniform");
                    eat_f64(&mut h, *lo);
                    eat_f64(&mut h, *hi);
                    eat_f64(&mut h, *q);
                }
                Domain::Normal { mean, std } => {
                    eat(&mut h, b"normal");
                    eat_f64(&mut h, *mean);
                    eat_f64(&mut h, *std);
                }
                Domain::Range { lo, hi } => {
                    eat(&mut h, b"range");
                    eat(&mut h, &lo.to_le_bytes());
                    eat(&mut h, &hi.to_le_bytes());
                }
                Domain::Choice(vals) => {
                    eat(&mut h, b"choice");
                    for v in vals {
                        // Variant tag first: Int(n) and F64(from_bits(n))
                        // share a byte encoding, so untagged values would
                        // let differently-typed choices collide.
                        match v {
                            ParamValue::F64(x) => {
                                eat(&mut h, b"f");
                                eat_f64(&mut h, *x);
                            }
                            ParamValue::Int(i) => {
                                eat(&mut h, b"i");
                                eat(&mut h, &i.to_le_bytes());
                            }
                            ParamValue::Str(s) => {
                                eat(&mut h, b"s");
                                eat(&mut h, s.as_bytes());
                            }
                        }
                        eat(&mut h, &[0xFE]); // value separator
                    }
                }
                Domain::Custom(d) => {
                    eat(&mut h, b"custom");
                    eat(&mut h, d.name().as_bytes());
                }
            }
            eat(&mut h, &[0xFD]); // param separator
        }
        h
    }

    /// The paper's heuristic for the Monte-Carlo acquisition sample count:
    /// grows with the number of parameters and the log-cardinality of the
    /// space, clamped to keep each acquisition call bounded. User-overridable
    /// via `RunConfig::mc_samples`.
    pub fn mc_samples_heuristic(&self) -> usize {
        let d = self.len().max(1) as f64;
        let logcard = self.cardinality_estimate().max(1.0).ln();
        let n = 400.0 * d + 100.0 * logcard;
        (n as usize).clamp(1000, 10_000)
    }
}

/// Fluent builder mirroring the paper's dict-style space definitions.
#[derive(Default)]
pub struct SearchSpaceBuilder {
    params: Vec<Param>,
}

impl SearchSpaceBuilder {
    fn push(mut self, name: &str, domain: Domain) -> Self {
        assert!(
            !self.params.iter().any(|p| p.name == name),
            "duplicate parameter '{name}'"
        );
        self.params.push(Param { name: name.to_string(), domain });
        self
    }

    /// `"x": uniform(lo, hi)` — continuous uniform.
    pub fn uniform(self, name: &str, lo: f64, hi: f64) -> Self {
        assert!(hi > lo, "uniform({name}): hi must exceed lo");
        self.push(name, Domain::Uniform { lo, hi })
    }

    /// `"x": loguniform(lo, hi)` — the paper's predefined distribution.
    pub fn loguniform(self, name: &str, lo: f64, hi: f64) -> Self {
        assert!(lo > 0.0 && hi > lo, "loguniform({name}): need 0 < lo < hi");
        self.push(name, Domain::LogUniform { lo, hi })
    }

    /// Uniform quantized to multiples of q.
    pub fn quniform(self, name: &str, lo: f64, hi: f64, q: f64) -> Self {
        assert!(hi > lo && q > 0.0, "quniform({name}): bad arguments");
        self.push(name, Domain::QUniform { lo, hi, q })
    }

    /// Normal(mean, std).
    pub fn normal(self, name: &str, mean: f64, std: f64) -> Self {
        assert!(std > 0.0, "normal({name}): std must be positive");
        self.push(name, Domain::Normal { mean, std })
    }

    /// Python `range(lo, hi)`: integers lo..hi-1 inclusive.
    pub fn range(self, name: &str, lo: i64, hi: i64) -> Self {
        assert!(hi > lo, "range({name}): hi must exceed lo");
        self.push(name, Domain::Range { lo, hi: hi - 1 })
    }

    /// Inclusive integer interval.
    pub fn int(self, name: &str, lo: i64, hi: i64) -> Self {
        assert!(hi >= lo, "int({name}): hi must be >= lo");
        self.push(name, Domain::Range { lo, hi })
    }

    /// Categorical over strings (Python list of str).
    pub fn choice(self, name: &str, values: &[&str]) -> Self {
        assert!(!values.is_empty(), "choice({name}): empty values");
        self.push(
            name,
            Domain::Choice(values.iter().map(|s| ParamValue::Str(s.to_string())).collect()),
        )
    }

    /// Categorical over arbitrary values.
    pub fn choice_values(self, name: &str, values: Vec<ParamValue>) -> Self {
        assert!(!values.is_empty(), "choice_values({name}): empty values");
        self.push(name, Domain::Choice(values))
    }

    /// Custom distribution (scipy.stats-style extension point).
    pub fn custom(self, name: &str, d: Arc<dyn Distribution>) -> Self {
        self.push(name, Domain::Custom(d))
    }

    pub fn build(self) -> SearchSpace {
        SearchSpace { params: self.params }
    }
}

/// The paper's Listing 1: XGBoost XGBClassifier space (reused by examples,
/// tests and the Fig. 2 harness).
pub fn xgboost_space() -> SearchSpace {
    SearchSpace::builder()
        .uniform("learning_rate", 0.0, 1.0)
        .uniform("gamma", 0.0, 5.0)
        .range("max_depth", 1, 10)
        .range("n_estimators", 1, 300)
        .choice("booster", &["gbtree", "gblinear", "dart"])
        .build()
}

/// The paper's Listing 2: SVM space (C uniform, gamma loguniform).
pub fn svm_space() -> SearchSpace {
    SearchSpace::builder()
        .uniform("c", 0.01, 100.0)
        .loguniform("gamma", 1e-4, 1e3)
        .build()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn xgboost_space_matches_listing1() {
        let s = xgboost_space();
        assert_eq!(s.len(), 5);
        // 100 * 500(gamma~100) levels... cardinality must be ~1e6 as in §1.
        assert!(s.cardinality_estimate() >= 1e6);
        assert_eq!(s.encoded_dim(), 7); // 4 numeric + 3-way one-hot
    }

    #[test]
    fn samples_respect_domains() {
        let s = xgboost_space();
        let mut rng = Pcg64::new(1);
        for _ in 0..500 {
            let c = s.sample(&mut rng);
            let lr = c.get_f64("learning_rate").unwrap();
            assert!((0.0..1.0).contains(&lr));
            let depth = c.get_i64("max_depth").unwrap();
            assert!((1..10).contains(&depth), "range(1,10) excludes 10");
            let booster = c.get_str("booster").unwrap();
            assert!(["gbtree", "gblinear", "dart"].contains(&booster));
        }
    }

    #[test]
    fn loguniform_spans_decades() {
        let s = svm_space();
        let mut rng = Pcg64::new(2);
        let mut small = 0;
        let mut large = 0;
        for _ in 0..2000 {
            let g = s.sample(&mut rng).get_f64("gamma").unwrap();
            assert!((1e-4..1e3).contains(&g));
            if g < 1e-2 {
                small += 1;
            }
            if g > 1.0 {
                large += 1;
            }
        }
        // log-uniform: ~2/7 of draws below 1e-2, ~3/7 above 1.
        assert!(small > 350 && large > 500, "small={small} large={large}");
    }

    #[test]
    fn quniform_quantizes() {
        let s = SearchSpace::builder().quniform("q", 0.0, 1.0, 0.25).build();
        let mut rng = Pcg64::new(3);
        for _ in 0..100 {
            let v = s.sample(&mut rng).get_f64("q").unwrap();
            let r = (v / 0.25).round() * 0.25;
            assert!((v - r).abs() < 1e-12);
        }
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let s = xgboost_space();
        let a = s.sample_n(&mut Pcg64::new(9), 10);
        let b = s.sample_n(&mut Pcg64::new(9), 10);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "duplicate parameter")]
    fn duplicate_names_rejected() {
        let _ = SearchSpace::builder().uniform("x", 0.0, 1.0).uniform("x", 0.0, 2.0);
    }

    #[test]
    fn fingerprint_is_stable_and_sensitive() {
        // Stable across independent constructions of the same space…
        assert_eq!(xgboost_space().fingerprint(), xgboost_space().fingerprint());
        assert_eq!(svm_space().fingerprint(), svm_space().fingerprint());
        // …and different for different structure, bounds, names, or order.
        assert_ne!(xgboost_space().fingerprint(), svm_space().fingerprint());
        let a = SearchSpace::builder().uniform("x", 0.0, 1.0).build();
        let bounds = SearchSpace::builder().uniform("x", 0.0, 2.0).build();
        let name = SearchSpace::builder().uniform("y", 0.0, 1.0).build();
        let kind = SearchSpace::builder().quniform("x", 0.0, 1.0, 0.1).build();
        assert_ne!(a.fingerprint(), bounds.fingerprint());
        assert_ne!(a.fingerprint(), name.fingerprint());
        assert_ne!(a.fingerprint(), kind.fingerprint());
        let ab = SearchSpace::builder().uniform("a", 0.0, 1.0).uniform("b", 0.0, 1.0).build();
        let ba = SearchSpace::builder().uniform("b", 0.0, 1.0).uniform("a", 0.0, 1.0).build();
        assert_ne!(ab.fingerprint(), ba.fingerprint(), "parameter order matters");
        let c1 = SearchSpace::builder().choice("m", &["a", "b"]).build();
        let c2 = SearchSpace::builder().choice("m", &["a", "c"]).build();
        assert_ne!(c1.fingerprint(), c2.fingerprint(), "choice values matter");
        // Same bytes, different variant: Int(1) vs F64 with bit pattern 1.
        let ci = SearchSpace::builder()
            .choice_values("m", vec![ParamValue::Int(1)])
            .build();
        let cf = SearchSpace::builder()
            .choice_values("m", vec![ParamValue::F64(f64::from_bits(1))])
            .build();
        assert_ne!(ci.fingerprint(), cf.fingerprint(), "choice value types matter");
    }

    #[test]
    fn mc_heuristic_scales_with_space() {
        let small = svm_space().mc_samples_heuristic();
        let large = xgboost_space().mc_samples_heuristic();
        assert!(large > small, "{large} vs {small}");
        assert!((1000..=10_000).contains(&small));
        assert!((1000..=10_000).contains(&large));
    }

    #[test]
    fn property_sample_always_in_domain() {
        check("range samples in bounds", 128, |g| {
            let lo = g.rng().uniform(-100.0, 100.0) as i64;
            let span = g.usize_range(1, 50) as i64;
            let s = SearchSpace::builder().int("v", lo, lo + span).build();
            let v = s.sample(&mut g.rng().split()).get_i64("v").unwrap();
            if v < lo || v > lo + span {
                return Err(format!("{v} outside [{lo}, {}]", lo + span));
            }
            Ok(())
        });
    }
}
