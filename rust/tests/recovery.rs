//! Crash-injection tests for the run journal + resume path.
//!
//! The journal is the run's only persistent state, so "kill the process
//! after event k" is exactly "truncate the journal to its first k events"
//! — the harness runs one journaled uninterrupted run, then replays a
//! crash at *every* event boundary (which subsumes k = 0, k = 1,
//! mid-batch, and last-iteration kills) and asserts the resumed run
//! reproduces the uninterrupted `TuningResult`'s best config, history, and
//! best-series exactly, in both execution modes. A real mid-objective
//! `panic!` (not just a synthetic truncation) is also exercised, as are
//! torn trailing lines, retry budgets across restarts for
//! `Lost(Crashed)`-in-flight work, and bit-identity of the
//! recovery-rebuilt GP Cholesky factor.
//!
//! With `--journal-segment-events` the journal is a directory of sealed,
//! checksummed segment files plus one active tail, and "kill after event
//! k" gains new shapes: mid-rotation (seal written, successor absent or
//! embryonic; torn seal line) and mid-compaction (stray staging file;
//! checkpoint renamed in but covered segments not yet deleted). The
//! segmented sweeps below reconstruct every one of those disk states from
//! a finished run and demand the identical result back.

use mango::coordinator::{ExecutionMode, ReplayMode, Tuner, TunerConfig};
use mango::gp::{fit_posterior, GpParams};
use mango::linalg::Matrix;
use mango::optimizer::bayesian::BayesianCore;
use mango::optimizer::{GpOptions, History, OptimizerKind, SurrogateBackend};
use mango::optimizer::prune::PrunerKind;
use mango::persist::{
    compact, read_journal, read_run, recover, EventOutcome, JournalEvent, JournalFault,
    JournalLayout, JournalPolicy, Replay,
};
use mango::scheduler::celery::CelerySimConfig;
use mango::scheduler::{SchedulerKind, TrialReporter};
use mango::space::{svm_space, Config, Encoder, SearchSpace};
use std::path::{Path, PathBuf};
use std::time::Duration;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("mango_recovery_{}_{name}.jsonl", std::process::id()))
}

fn quad(cfg: &Config) -> Option<f64> {
    let c = cfg.get_f64("c")?;
    Some(-(c - 60.0) * (c - 60.0))
}

fn base_config(mode: ExecutionMode) -> TunerConfig {
    TunerConfig {
        optimizer: OptimizerKind::Hallucination,
        num_iterations: 5,
        batch_size: 2,
        backend: SurrogateBackend::Native,
        scheduler: SchedulerKind::Serial,
        mc_samples: 128,
        seed: 13,
        mode,
        ..Default::default()
    }
}

/// Byte offsets of every `\n` + 1 — i.e. every possible "the process was
/// killed exactly between two journal writes" file length.
fn event_boundaries(bytes: &[u8]) -> Vec<usize> {
    bytes
        .iter()
        .enumerate()
        .filter(|(_, &b)| b == b'\n')
        .map(|(i, _)| i + 1)
        .collect()
}

fn assert_result_eq(
    resumed: &mango::coordinator::TuningResult,
    baseline: &mango::coordinator::TuningResult,
    context: &str,
) {
    assert_eq!(resumed.best_params, baseline.best_params, "{context}: best_params differ");
    assert_eq!(
        resumed.best_objective, baseline.best_objective,
        "{context}: best_objective differs"
    );
    assert_eq!(resumed.history, baseline.history, "{context}: history differs");
    assert_eq!(resumed.best_series, baseline.best_series, "{context}: best_series differs");
    assert_eq!(resumed.evaluations, baseline.evaluations, "{context}: eval count differs");
}

/// The acceptance-criterion harness: crash at every event boundary, resume,
/// and demand the uninterrupted result back.
fn crash_at_every_boundary(mode: ExecutionMode, label: &str) {
    crash_at_every_boundary_with(base_config(mode), quad, label);
}

/// Same sweep, parameterized over the run config and objective — the
/// `--replay stable` variants reuse it with parallel schedulers and a
/// wall-clock-jittered objective.
fn crash_at_every_boundary_with(
    cfg: TunerConfig,
    objective: fn(&Config) -> Option<f64>,
    label: &str,
) {
    let space = svm_space();
    let budget = cfg.num_iterations * cfg.batch_size;

    // Baseline: un-journaled uninterrupted run.
    let baseline = Tuner::new(space.clone(), cfg.clone()).maximize(objective).unwrap();
    assert_eq!(
        baseline.evaluations + baseline.lost as usize,
        budget,
        "{label}: every proposal must conclude"
    );

    // Journaled uninterrupted run must be byte-for-byte transparent.
    let full_path = tmp(&format!("{label}_full"));
    let journaled = Tuner::new(space.clone(), cfg.clone())
        .with_journal(&full_path)
        .maximize(objective)
        .unwrap();
    assert_result_eq(&journaled, &baseline, &format!("{label}: journaling changed the run"));

    let bytes = std::fs::read(&full_path).unwrap();
    let boundaries = event_boundaries(&bytes);
    assert!(
        boundaries.len() > 12,
        "{label}: expected a rich event stream, got {} lines",
        boundaries.len()
    );

    // k = 0 (header only), k = 1, every mid-batch point, the last
    // completion, and the finished journal are all boundaries.
    let case_path = tmp(&format!("{label}_case"));
    for (idx, &cut) in boundaries.iter().enumerate() {
        std::fs::write(&case_path, &bytes[..cut]).unwrap();
        let mut resumed_tuner = Tuner::resume_from(space.clone(), &case_path)
            .unwrap_or_else(|e| panic!("{label}: resume at boundary {idx} failed: {e:#}"));
        let resumed = resumed_tuner
            .maximize(objective)
            .unwrap_or_else(|e| panic!("{label}: resumed run at boundary {idx} failed: {e:#}"));
        assert_result_eq(&resumed, &baseline, &format!("{label}: crash at event {idx}"));
    }

    // A torn half-written line after a boundary must change nothing.
    let mid = boundaries[boundaries.len() / 2];
    let mut torn = bytes[..mid].to_vec();
    torn.extend_from_slice(br#"{"e":"sync_eval","iter":9,"conf"#);
    std::fs::write(&case_path, &torn).unwrap();
    let resumed = Tuner::resume_from(space.clone(), &case_path)
        .unwrap()
        .maximize(objective)
        .unwrap();
    assert_result_eq(&resumed, &baseline, &format!("{label}: torn trailing line"));

    std::fs::remove_file(&full_path).ok();
    std::fs::remove_file(&case_path).ok();
}

#[test]
fn sync_crash_at_any_point_resumes_to_identical_result() {
    crash_at_every_boundary(ExecutionMode::Sync, "sync");
}

#[test]
fn async_crash_at_any_point_resumes_to_identical_result() {
    crash_at_every_boundary(ExecutionMode::Async, "async");
}

/// A real kill, not a synthetic truncation: the objective panics mid-run,
/// the per-line-flushed journal survives on disk, and the resumed run
/// still reproduces the uninterrupted result.
#[test]
fn panic_mid_objective_leaves_a_resumable_journal() {
    use std::panic::AssertUnwindSafe;
    use std::sync::atomic::{AtomicUsize, Ordering};

    let space = svm_space();
    let cfg = TunerConfig {
        optimizer: OptimizerKind::Hallucination,
        num_iterations: 6,
        batch_size: 1,
        backend: SurrogateBackend::Native,
        mc_samples: 128,
        seed: 5,
        ..Default::default()
    };
    let baseline = Tuner::new(space.clone(), cfg.clone()).maximize(quad).unwrap();

    let path = tmp("panic");
    let calls = AtomicUsize::new(0);
    let crashed = std::panic::catch_unwind(AssertUnwindSafe(|| {
        let mut t = Tuner::new(space.clone(), cfg.clone()).with_journal(&path);
        t.maximize(|c: &Config| {
            if calls.fetch_add(1, Ordering::SeqCst) + 1 == 4 {
                panic!("injected coordinator crash");
            }
            quad(c)
        })
    }));
    assert!(crashed.is_err(), "the injected panic must abort the run");

    let resumed = Tuner::resume_from(space, &path).unwrap().maximize(quad).unwrap();
    assert_result_eq(&resumed, &baseline, "panic-killed run");
    std::fs::remove_file(&path).ok();
}

/// Satellite: when every worker dies without reporting (a real
/// worker-thread panic, not a simulated fault fate), the event loop's
/// bail-out journals each still-in-flight proposal as a terminal
/// `Lost(Crashed)` before the scope join propagates the panic — so a
/// resume agrees with the crashed process instead of silently
/// re-enqueueing work the dead run already concluded.
#[test]
fn worker_panic_bailout_journals_lost_crashed_terminals() {
    use mango::scheduler::LossReason;
    use std::panic::AssertUnwindSafe;
    use std::sync::atomic::{AtomicUsize, Ordering};

    let space = svm_space();
    let cfg = TunerConfig {
        optimizer: OptimizerKind::Random,
        num_iterations: 6,
        batch_size: 3,
        backend: SurrogateBackend::Native,
        scheduler: SchedulerKind::Threaded,
        workers: 1, // a single panic kills the whole pool
        seed: 9,
        mode: ExecutionMode::Async,
        ..Default::default()
    };
    let path = tmp("worker_panic");
    let calls = AtomicUsize::new(0);
    let crashed = std::panic::catch_unwind(AssertUnwindSafe(|| {
        let mut t = Tuner::new(space.clone(), cfg.clone()).with_journal(&path);
        t.maximize(|c: &Config| {
            if calls.fetch_add(1, Ordering::SeqCst) + 1 == 3 {
                panic!("injected worker panic");
            }
            quad(c)
        })
    }));
    assert!(crashed.is_err(), "the scope join must propagate the worker panic");

    // The crashed process's own journal concludes everything in flight.
    let prefix = read_journal(&path).unwrap().events;
    let crashed_pids: Vec<u64> = prefix
        .iter()
        .filter_map(|e| match e {
            JournalEvent::AsyncComplete {
                pid,
                outcome: EventOutcome::Lost(LossReason::Crashed),
                ..
            } => Some(*pid),
            _ => None,
        })
        .collect();
    assert!(
        !crashed_pids.is_empty(),
        "in-flight proposals at the pool collapse must be journaled as Lost(Crashed)"
    );

    // Resume completes the remaining budget and honors the terminals.
    let resumed = Tuner::resume_from(space, &path).unwrap().maximize(quad).unwrap();
    assert_eq!(
        resumed.evaluations + resumed.lost as usize,
        18,
        "6 iterations x 3: every proposal concludes exactly once, got {} + {}",
        resumed.evaluations,
        resumed.lost
    );
    assert!(
        resumed.lost >= crashed_pids.len() as u64,
        "replayed Lost(Crashed) terminals must be counted, not re-run"
    );

    // Stitched journal audit: a concluded proposal is never re-enqueued by
    // the resumed process, and concludes exactly once overall.
    let stitched = read_journal(&path).unwrap().events;
    for pid in &crashed_pids {
        let resubmitted_after_crash = stitched[prefix.len()..]
            .iter()
            .any(|e| matches!(e, JournalEvent::AsyncSubmit { pid: p, .. } if p == pid));
        assert!(!resubmitted_after_crash, "proposal {pid} was re-enqueued after its terminal");
        let terminals = stitched
            .iter()
            .filter(|e| {
                matches!(e, JournalEvent::AsyncComplete { pid: p, outcome, .. }
                         if p == pid && !matches!(outcome, EventOutcome::Resubmitted(_)))
            })
            .count();
        assert_eq!(terminals, 1, "proposal {pid} concluded {terminals} times");
    }
    std::fs::remove_file(&path).ok();
}

/// `Lost(Crashed)` work in flight at the kill: the retry budget is a
/// per-proposal property of the *run*, not of one process lifetime — a
/// resumed run must honor retries already consumed before the crash and
/// never exceed `max_retries` resubmissions per proposal overall.
#[test]
fn lost_in_flight_at_crash_honors_retry_budget_across_restarts() {
    let space = svm_space();
    let celery = CelerySimConfig {
        workers: 3,
        base_latency_ms: 0.3,
        straggler_prob: 0.0,
        straggler_factor: 1.0,
        crash_prob: 0.45,
        result_timeout: Duration::from_secs(10),
    };
    let cfg = TunerConfig {
        optimizer: OptimizerKind::Random,
        num_iterations: 7,
        batch_size: 2,
        backend: SurrogateBackend::Native,
        scheduler: SchedulerKind::Celery,
        workers: 3,
        max_retries: 2,
        seed: 21,
        mode: ExecutionMode::Async,
        celery: Some(celery.clone()),
        ..Default::default()
    };

    // Uninterrupted journaled run under heavy fault injection.
    let full_path = tmp("retry_full");
    let full = Tuner::new(space.clone(), cfg.clone())
        .with_journal(&full_path)
        .maximize(quad)
        .unwrap();
    assert!(full.retried > 0, "crash_prob 0.45 must trigger retries (got none)");
    let bytes = std::fs::read(&full_path).unwrap();

    // Kill right after the first Resubmitted completion: that proposal is
    // mid-retry and in flight at the crash.
    let boundaries = event_boundaries(&bytes);
    let events = read_journal(&full_path).unwrap().events;
    let first_resub = events
        .iter()
        .position(|e| {
            matches!(
                e,
                JournalEvent::AsyncComplete { outcome: EventOutcome::Resubmitted(_), .. }
            )
        })
        .expect("a Resubmitted event must exist");
    // events[i] lives on journal line i+2 → its end is boundary i+1.
    let cut = boundaries[first_resub + 1];
    let case_path = tmp("retry_case");
    std::fs::write(&case_path, &bytes[..cut]).unwrap();

    // No `with_celery` here: the v2 journal header carries the fault-model
    // override and `resume_from` re-applies it (the old API required the
    // caller to re-supply it or silently simulate a default cluster).
    let mut resumed_tuner = Tuner::resume_from(space, &case_path).unwrap();
    assert_eq!(
        resumed_tuner.config().celery.as_ref(),
        Some(&celery),
        "resume must restore the journaled fault model"
    );
    let resumed = resumed_tuner.maximize(quad).unwrap();
    assert_eq!(
        resumed.evaluations + resumed.lost as usize,
        14,
        "every proposal must conclude exactly once (done or lost), got {} + {}",
        resumed.evaluations,
        resumed.lost
    );

    // Audit the stitched journal (pre-crash prefix + post-resume suffix):
    // per proposal, at most max_retries resubmissions — across restarts.
    let stitched = read_journal(&case_path).unwrap().events;
    let mut resubs: std::collections::BTreeMap<u64, usize> = std::collections::BTreeMap::new();
    let mut terminals: std::collections::BTreeMap<u64, usize> = std::collections::BTreeMap::new();
    for ev in &stitched {
        if let JournalEvent::AsyncComplete { pid, outcome, .. } = ev {
            match outcome {
                EventOutcome::Resubmitted(_) => *resubs.entry(*pid).or_default() += 1,
                _ => *terminals.entry(*pid).or_default() += 1,
            }
        }
    }
    assert!(!resubs.is_empty(), "the mid-retry proposal must appear in the stitched journal");
    for (pid, n) in &resubs {
        assert!(
            *n <= cfg.max_retries,
            "proposal {pid}: {n} resubmissions exceed max_retries {} across restarts",
            cfg.max_retries
        );
    }
    for (pid, n) in &terminals {
        assert_eq!(*n, 1, "proposal {pid} concluded {n} times");
    }
    // The mid-retry proposal's journaled retry counter was carried across
    // the restart: its re-enqueue submit must show retries >= 1.
    let JournalEvent::AsyncComplete { pid: crashed_pid, .. } = &events[first_resub] else {
        unreachable!()
    };
    let re_enqueued_with_budget = stitched.iter().any(|e| {
        matches!(e, JournalEvent::AsyncSubmit { pid, retries, .. }
                 if pid == crashed_pid && *retries >= 1)
    });
    assert!(
        re_enqueued_with_budget,
        "proposal {crashed_pid} must be re-enqueued with its consumed retry budget"
    );

    std::fs::remove_file(&full_path).ok();
    std::fs::remove_file(&case_path).ok();
}

/// Satellite: the Celery fault-sim override is journaled in the v2 header
/// and re-applied by `resume_from` — a crash + resume must continue under
/// the configured cluster (and the resumed run's own journal header keeps
/// carrying it, so a second crash resumes identically).
#[test]
fn celery_fault_model_survives_crash_and_resume_from_header_alone() {
    let space = svm_space();
    let celery = CelerySimConfig {
        workers: 2,
        base_latency_ms: 0.2,
        straggler_prob: 0.0,
        straggler_factor: 1.0,
        crash_prob: 0.0, // reliable but custom: the override is detectable
        result_timeout: Duration::from_millis(1234),
    };
    let cfg = TunerConfig {
        optimizer: OptimizerKind::Random,
        num_iterations: 4,
        batch_size: 2,
        backend: SurrogateBackend::Native,
        scheduler: SchedulerKind::Celery,
        workers: 2,
        seed: 8,
        mode: ExecutionMode::Async,
        celery: Some(celery.clone()),
        ..Default::default()
    };
    let path = tmp("celery_header");
    Tuner::new(space.clone(), cfg)
        .with_journal(&path)
        .maximize(quad)
        .unwrap();

    // Crash mid-run: truncate to an early boundary, resume WITHOUT
    // re-supplying the override.
    let bytes = std::fs::read(&path).unwrap();
    let boundaries = event_boundaries(&bytes);
    std::fs::write(&path, &bytes[..boundaries[boundaries.len() / 2]]).unwrap();
    let mut resumed = Tuner::resume_from(space.clone(), &path).unwrap();
    assert_eq!(
        resumed.config().celery.as_ref(),
        Some(&celery),
        "the journal header must supply the fault model on resume"
    );
    let result = resumed.maximize(quad).unwrap();
    assert_eq!(result.evaluations, 8, "resumed run completes the budget");

    // A second resume (crash-after-resume) still finds the override in the
    // stitched journal's header.
    let again = Tuner::resume_from(space, &path).unwrap();
    assert_eq!(again.config().celery.as_ref(), Some(&celery));
    std::fs::remove_file(&path).ok();
}

/// Threaded sync: completion order inside a batch is nondeterministic, so
/// exact-trajectory equality is out of scope — but a crash + resume must
/// still complete the full budget with a well-formed stitched journal.
#[test]
fn threaded_sync_crash_resume_completes_the_budget() {
    let space = svm_space();
    let cfg = TunerConfig {
        optimizer: OptimizerKind::Random,
        num_iterations: 6,
        batch_size: 4,
        backend: SurrogateBackend::Native,
        scheduler: SchedulerKind::Threaded,
        workers: 4,
        seed: 3,
        ..Default::default()
    };
    let path = tmp("threaded");
    Tuner::new(space.clone(), cfg.clone())
        .with_journal(&path)
        .maximize(quad)
        .unwrap();
    let bytes = std::fs::read(&path).unwrap();
    let boundaries = event_boundaries(&bytes);
    // Kill somewhere past the first couple of iterations.
    let cut = boundaries[boundaries.len() / 3];
    std::fs::write(&path, &bytes[..cut]).unwrap();
    let resumed = Tuner::resume_from(space, &path).unwrap().maximize(quad).unwrap();
    assert_eq!(resumed.evaluations, 24, "6 iterations x 4 configs, stitched across the crash");
    assert_eq!(resumed.best_series.len(), 6);
    let stitched = read_journal(&path).unwrap();
    let rounds = stitched
        .events
        .iter()
        .filter(|e| matches!(e, JournalEvent::SyncRound { .. }))
        .count();
    assert_eq!(rounds, 6, "every iteration must have a commit marker");
    std::fs::remove_file(&path).ok();
}

/// Satellite: the recovery-rebuilt `CholeskyState` must be bit-identical
/// to the factor the uninterrupted run carried at the same history prefix
/// (extends the incremental == scratch property to the recovery path).
#[test]
fn rehydrated_cholesky_state_is_bit_identical_to_uninterrupted() {
    let space = svm_space();
    let opts = GpOptions {
        backend: SurrogateBackend::Native,
        fixed_beta: Some(2.0),
        ..Default::default()
    };

    // Build a deterministic 12-point history.
    let mut rng = mango::util::rng::Pcg64::new(77);
    let mut history = History::new();
    for cfg in space.sample_n(&mut rng, 12) {
        let v = quad(&cfg).unwrap();
        history.push(cfg, v);
    }

    // "Uninterrupted" core: grows its cached state across three scheduling
    // rounds (4 → 8 → 12 observations, append-only prefix growth), the way
    // a live run would.
    let prefix = |n: usize| {
        let mut h = History::new();
        for i in 0..n {
            h.push(history.configs()[i].clone(), history.values()[i]);
        }
        h
    };
    let mut live = BayesianCore::new(space.clone(), opts.clone()).unwrap();
    for n in [4usize, 8, 12] {
        live.fit_and_score(&prefix(n), 1, &mut rng).unwrap();
    }

    // Crash + recovery: a fresh core rehydrated from the replayed rows.
    let mut recovered = BayesianCore::new(space.clone(), opts).unwrap();
    recovered.rehydrate(&history, 3).unwrap();
    assert_eq!(recovered.rounds, 3, "adaptive-beta clock restored");

    let d = Encoder::new(&space).dims();
    let mut params = GpParams::new(d);
    params.noise = 1e-3; // GpOptions::default().noise
    let live_state = live
        .cached_state(&params)
        .expect("uninterrupted run must hold a cached state");
    let rec_state = recovered
        .cached_state(&params)
        .expect("rehydration must rebuild the cached state");
    assert_eq!(live_state.rows(), 12);
    assert_eq!(rec_state.rows(), 12);
    assert_eq!(
        rec_state.factor(),
        live_state.factor(),
        "recovery-rebuilt factor must be bit-identical to the live run's"
    );

    // And both must equal the ground-truth factor over the same rows.
    let encoder = Encoder::new(&space);
    let flat = encoder.encode_batch(history.configs());
    let x = Matrix::from_vec(history.len(), d, flat);
    let y = vec![0.0; history.len()]; // y never enters the factor
    let (truth, _) = fit_posterior(&x, &y, &params, None).unwrap();
    assert_eq!(rec_state.factor(), &truth.chol, "factor must match a scratch fit exactly");
}

/// Early stop must stay latched across a crash: the live loop stops
/// proposing once the no-improvement streak hits the threshold, but keeps
/// draining in-flight completions — and one of those can improve the best
/// and reset the streak. A resumed run must not look at the final streak,
/// decide the run never stopped, and burn the remaining budget.
#[test]
fn resumed_async_run_stays_early_stopped_after_post_stop_improvement() {
    use mango::persist::{EventOutcome, JournalEvent, JournalWriter, RunHeader, SenseTag};
    use mango::space::ParamValue;

    let space = svm_space();
    let tc = TunerConfig {
        optimizer: OptimizerKind::Random,
        num_iterations: 10,
        batch_size: 1,
        backend: SurrogateBackend::Native,
        scheduler: SchedulerKind::Serial,
        early_stop: Some(1),
        mode: ExecutionMode::Async,
        seed: 4,
        ..Default::default()
    };
    let cfg_pt = |c: f64| {
        Config::new(vec![
            ("c".into(), ParamValue::F64(c)),
            ("gamma".into(), ParamValue::F64(1.0)),
        ])
    };
    // Journal the crashed run by hand: pid1 concludes without improvement
    // (streak 1 >= early_stop 1 → the live loop latched the stop), then
    // the still-in-flight pid2 improves the best (streak resets to 0),
    // then the coordinator dies.
    let path = tmp("early_stop_latch");
    {
        let header = RunHeader {
            space_fp: space.fingerprint(),
            sense: SenseTag::Maximize,
            run: tc.to_run_config(),
            celery: None,
        };
        let mut w = JournalWriter::create(&path, &header).unwrap();
        for (pid, c) in [(0u64, 10.0), (1, 20.0), (2, 30.0)] {
            w.append(&JournalEvent::AsyncPropose { pid, rounds: 0, config: cfg_pt(c) })
                .unwrap();
            w.append(&JournalEvent::AsyncSubmit {
                pid,
                task: pid,
                retries: 0,
                cutoff: 0,
                backoff_ms: 0.0,
            })
            .unwrap();
        }
        for (pid, v) in [(0u64, 1.0), (1, 1.0), (2, 2.0)] {
            w.append(&JournalEvent::AsyncComplete {
                pid,
                task: pid,
                retries: 0,
                outcome: EventOutcome::Done(v),
                queue_ms: 0.1,
                eval_ms: 0.1,
            })
            .unwrap();
        }
    }
    let resumed = Tuner::resume_from(space, &path)
        .unwrap()
        .maximize(|_| Some(0.0))
        .unwrap();
    assert_eq!(
        resumed.evaluations, 3,
        "a resumed early-stopped run must not propose new work (streak reset by a \
         post-stop improvement must not un-latch the stop)"
    );
    assert_eq!(resumed.best_objective, 2.0);
    assert_eq!(resumed.best_series, vec![1.0, 1.0, 2.0]);
    std::fs::remove_file(&path).ok();
}

/// `quad` split into three intermediate reports ramping toward the final
/// value, honouring prune decisions by returning early.
fn staged_quad(cfg: &Config, reporter: &TrialReporter) -> Option<f64> {
    let full = quad(cfg)?;
    for step in 0..3u64 {
        let v = full * ((step + 1) as f64) / 3.0;
        if !reporter.report(step, v) {
            return Some(v);
        }
    }
    Some(full)
}

/// Tentpole acceptance criterion: with a pruner active, "kill the process
/// after event k" for *every* k — which includes every intermediate-report
/// boundary and every `Pruned` completion boundary — then resume, and the
/// stitched run reproduces the uninterrupted result (best, history with
/// censored entries, best-series, and the pruning counters). The resumed
/// process re-derives the pruner's rung/median state from the journaled
/// reports rather than trusting the crashed process.
#[test]
fn pruned_async_crash_at_any_point_resumes_to_identical_result() {
    let space = svm_space();
    for (pruner, label) in [(PrunerKind::Median, "median"), (PrunerKind::Asha, "asha")] {
        let cfg = TunerConfig {
            optimizer: OptimizerKind::Hallucination,
            num_iterations: 5,
            batch_size: 2,
            backend: SurrogateBackend::Native,
            scheduler: SchedulerKind::Serial,
            mc_samples: 128,
            seed: 13,
            mode: ExecutionMode::Async,
            pruner,
            pruner_warmup: 1,
            asha_reduction: 2.0,
            ..Default::default()
        };

        // Baseline: un-journaled uninterrupted run.
        let baseline = Tuner::new(space.clone(), cfg.clone())
            .maximize_with_reports(staged_quad)
            .unwrap();
        assert!(baseline.pruned >= 1, "{label}: the staged workload must actually prune");
        assert!(baseline.reports >= 1, "{label}: reports must flow");

        // Journaled uninterrupted run must be transparent.
        let full_path = tmp(&format!("pruned_{label}_full"));
        let journaled = Tuner::new(space.clone(), cfg.clone())
            .with_journal(&full_path)
            .maximize_with_reports(staged_quad)
            .unwrap();
        assert_result_eq(&journaled, &baseline, &format!("{label}: journaling changed the run"));
        assert_eq!(journaled.pruned, baseline.pruned, "{label}: pruned counter drifted");

        // The boundary sweep must actually cover report and pruned-
        // completion boundaries, not just submits and completions.
        let events = read_journal(&full_path).unwrap().events;
        let n_reports = events
            .iter()
            .filter(|e| matches!(e, JournalEvent::AsyncReport { .. }))
            .count();
        let n_pruned = events
            .iter()
            .filter(|e| {
                matches!(
                    e,
                    JournalEvent::AsyncComplete { outcome: EventOutcome::Pruned { .. }, .. }
                )
            })
            .count();
        assert!(n_reports >= 1, "{label}: no async_report events journaled");
        assert_eq!(n_pruned as u64, baseline.pruned, "{label}: pruned terminals must be journaled");

        let bytes = std::fs::read(&full_path).unwrap();
        let boundaries = event_boundaries(&bytes);
        let case_path = tmp(&format!("pruned_{label}_case"));
        for (idx, &cut) in boundaries.iter().enumerate() {
            std::fs::write(&case_path, &bytes[..cut]).unwrap();
            let mut resumed_tuner = Tuner::resume_from(space.clone(), &case_path)
                .unwrap_or_else(|e| panic!("{label}: resume at boundary {idx} failed: {e:#}"));
            let resumed = resumed_tuner
                .maximize_with_reports(staged_quad)
                .unwrap_or_else(|e| panic!("{label}: resumed run at boundary {idx} failed: {e:#}"));
            assert_result_eq(&resumed, &baseline, &format!("{label}: crash at event {idx}"));
            assert_eq!(
                resumed.pruned, baseline.pruned,
                "{label}: crash at event {idx}: pruned counter drifted"
            );
        }
        std::fs::remove_file(&full_path).ok();
        std::fs::remove_file(&case_path).ok();
    }
}

/// Pre-v5 journals predate the segment/checkpoint layout (v4), the
/// replay/epoch machinery (v3), the pruning events (v2), or the celery
/// header (v1) — replaying any of them under v5 rules could silently
/// mis-fold a resumed run, so the reader must refuse every stale version
/// outright instead of guessing.
#[test]
fn stale_journal_versions_are_refused_loudly() {
    let space = svm_space();
    let path = tmp("stale_version_guard");
    Tuner::new(
        space.clone(),
        TunerConfig {
            optimizer: OptimizerKind::Random,
            num_iterations: 2,
            backend: SurrogateBackend::Native,
            ..Default::default()
        },
    )
    .with_journal(&path)
    .maximize(quad)
    .unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    for stale_version in 1..=4u32 {
        let stale = text.replacen(
            &format!("\"version\":{}", mango::persist::JOURNAL_VERSION),
            &format!("\"version\":{stale_version}"),
            1,
        );
        assert_ne!(stale, text, "version literal must be present to corrupt");
        std::fs::write(&path, stale).unwrap();
        let err = Tuner::resume_from(space.clone(), &path).unwrap_err();
        assert!(err.to_string().contains("version"), "v{stale_version}: got: {err:#}");
    }
    std::fs::remove_file(&path).ok();
}

/// Resuming against the wrong space must fail loudly, and a journal from a
/// different schema version must be refused (covered at unit level too —
/// this exercises the public `Tuner::resume_from` path end-to-end).
#[test]
fn resume_guards_fire_end_to_end() {
    let space = svm_space();
    let path = tmp("guards");
    Tuner::new(
        space,
        TunerConfig {
            optimizer: OptimizerKind::Random,
            num_iterations: 2,
            backend: SurrogateBackend::Native,
            ..Default::default()
        },
    )
    .with_journal(&path)
    .maximize(quad)
    .unwrap();

    // Wrong space.
    let other: SearchSpace = mango::space::xgboost_space();
    let err = Tuner::resume_from(other, &path).unwrap_err();
    assert!(err.to_string().contains("different search space"), "got: {err:#}");

    // Wrong schema version (also covers pre-celery v1 journals).
    let text = std::fs::read_to_string(&path).unwrap();
    let stale = text.replacen(
        &format!("\"version\":{}", mango::persist::JOURNAL_VERSION),
        "\"version\":99",
        1,
    );
    assert_ne!(stale, text, "version literal must be present to corrupt");
    std::fs::write(&path, stale).unwrap();
    let err = Tuner::resume_from(svm_space(), &path).unwrap_err();
    assert!(err.to_string().contains("version"), "got: {err:#}");
    std::fs::remove_file(&path).ok();
}

/// `quad` plus a per-config wall-clock jitter: shuffles parallel completion
/// order without touching the (deterministic) objective value — exactly the
/// nondeterminism `--replay stable` must absorb.
fn jittery_quad(cfg: &Config) -> Option<f64> {
    let c = cfg.get_f64("c")?;
    std::thread::sleep(Duration::from_millis(c as u64 % 4));
    Some(-(c - 60.0) * (c - 60.0))
}

fn stable_config(scheduler: SchedulerKind, workers: usize) -> TunerConfig {
    TunerConfig {
        optimizer: OptimizerKind::Hallucination,
        num_iterations: 5,
        batch_size: 2,
        backend: SurrogateBackend::Native,
        scheduler,
        workers,
        mc_samples: 128,
        seed: 13,
        mode: ExecutionMode::Async,
        replay: ReplayMode::Stable,
        ..Default::default()
    }
}

/// Tentpole acceptance criterion: under `--replay stable` the
/// crash-at-every-boundary sweep extends to the *threaded* scheduler —
/// completions arrive in wall-clock order but fold canonically, so every
/// resumed run reproduces the seed-matched uninterrupted run exactly.
#[test]
fn stable_threaded_crash_at_any_point_resumes_to_identical_result() {
    crash_at_every_boundary_with(
        stable_config(SchedulerKind::Threaded, 4),
        jittery_quad,
        "stable_threaded",
    );
}

/// Tentpole acceptance criterion, celery-sim flavor: latency jitter from
/// the simulated cluster shuffles arrival order; stable folding (with
/// fate draws keyed by proposal, not by wall-clock draw order) keeps the
/// trajectory byte-identical across every crash point.
#[test]
fn stable_celery_crash_at_any_point_resumes_to_identical_result() {
    let mut cfg = stable_config(SchedulerKind::Celery, 3);
    cfg.celery = Some(CelerySimConfig {
        workers: 3,
        base_latency_ms: 0.3,
        straggler_prob: 0.4,
        straggler_factor: 4.0,
        crash_prob: 0.0,
        result_timeout: Duration::from_secs(10),
    });
    crash_at_every_boundary_with(cfg, quad, "stable_celery");
}

/// Stable replay under a *faulty* celery cluster: worker crashes trigger
/// retries (with a journaled deterministic backoff schedule), and a kill
/// right after the first `Resubmitted` event — the proposal is mid-retry
/// and in flight — still resumes to the seed-matched uninterrupted result,
/// because fates are keyed by (proposal, attempt) and the re-enqueue
/// reuses the journaled cutoff/backoff instead of re-deriving them.
#[test]
fn stable_celery_mid_retry_crash_resumes_to_identical_result() {
    let space = svm_space();
    let celery = CelerySimConfig {
        workers: 3,
        base_latency_ms: 0.3,
        straggler_prob: 0.0,
        straggler_factor: 1.0,
        crash_prob: 0.4,
        result_timeout: Duration::from_secs(10),
    };
    let cfg = TunerConfig {
        optimizer: OptimizerKind::Random,
        num_iterations: 7,
        batch_size: 2,
        backend: SurrogateBackend::Native,
        scheduler: SchedulerKind::Celery,
        workers: 3,
        max_retries: 2,
        retry_backoff_ms: 2.0,
        seed: 21,
        mode: ExecutionMode::Async,
        replay: ReplayMode::Stable,
        celery: Some(celery),
        ..Default::default()
    };

    // Under keyed fates the faulty cluster is deterministic: the
    // un-journaled baseline is the ground truth even with crashes.
    let baseline = Tuner::new(space.clone(), cfg.clone()).maximize(quad).unwrap();
    assert!(baseline.retried > 0, "crash_prob 0.4 must trigger retries (got none)");

    let full_path = tmp("stable_retry_full");
    let journaled = Tuner::new(space.clone(), cfg.clone())
        .with_journal(&full_path)
        .maximize(quad)
        .unwrap();
    assert_result_eq(&journaled, &baseline, "stable faulty celery: journaling changed the run");
    assert_eq!(journaled.retried, baseline.retried, "retry schedule drifted under journaling");

    // Kill right after the first Resubmitted completion.
    let bytes = std::fs::read(&full_path).unwrap();
    let boundaries = event_boundaries(&bytes);
    let events = read_journal(&full_path).unwrap().events;
    let first_resub = events
        .iter()
        .position(|e| {
            matches!(e, JournalEvent::AsyncComplete { outcome: EventOutcome::Resubmitted(_), .. })
        })
        .expect("a Resubmitted event must exist");
    let case_path = tmp("stable_retry_case");
    std::fs::write(&case_path, &bytes[..boundaries[first_resub + 1]]).unwrap();
    let resumed = Tuner::resume_from(space, &case_path).unwrap().maximize(quad).unwrap();
    assert_result_eq(&resumed, &baseline, "stable faulty celery: mid-retry crash");

    std::fs::remove_file(&full_path).ok();
    std::fs::remove_file(&case_path).ok();
}

/// Stable replay with a pruner active: pruning decisions are filtered by
/// the journaled per-task visibility cutoff, so the crash sweep holds for
/// the full trajectory *and* the pruning counters on a parallel scheduler.
#[test]
fn stable_threaded_pruned_crash_at_any_point_resumes_to_identical_result() {
    let space = svm_space();
    let cfg = TunerConfig {
        optimizer: OptimizerKind::Hallucination,
        num_iterations: 5,
        batch_size: 2,
        backend: SurrogateBackend::Native,
        scheduler: SchedulerKind::Threaded,
        workers: 4,
        mc_samples: 128,
        seed: 13,
        mode: ExecutionMode::Async,
        replay: ReplayMode::Stable,
        pruner: PrunerKind::Median,
        pruner_warmup: 1,
        ..Default::default()
    };

    let staged = |cfg: &Config, reporter: &TrialReporter| {
        std::thread::sleep(Duration::from_millis(cfg.get_f64("c")? as u64 % 4));
        staged_quad(cfg, reporter)
    };
    let baseline =
        Tuner::new(space.clone(), cfg.clone()).maximize_with_reports(staged).unwrap();
    assert!(baseline.pruned >= 1, "the staged workload must actually prune");

    let full_path = tmp("stable_pruned_full");
    let journaled = Tuner::new(space.clone(), cfg.clone())
        .with_journal(&full_path)
        .maximize_with_reports(staged)
        .unwrap();
    assert_result_eq(&journaled, &baseline, "stable pruned: journaling changed the run");
    assert_eq!(journaled.pruned, baseline.pruned, "stable pruned: counter drifted");

    let bytes = std::fs::read(&full_path).unwrap();
    let boundaries = event_boundaries(&bytes);
    let case_path = tmp("stable_pruned_case");
    for (idx, &cut) in boundaries.iter().enumerate() {
        std::fs::write(&case_path, &bytes[..cut]).unwrap();
        let resumed = Tuner::resume_from(space.clone(), &case_path)
            .unwrap_or_else(|e| panic!("stable pruned: resume at boundary {idx} failed: {e:#}"))
            .maximize_with_reports(staged)
            .unwrap_or_else(|e| panic!("stable pruned: run at boundary {idx} failed: {e:#}"));
        assert_result_eq(&resumed, &baseline, &format!("stable pruned: crash at event {idx}"));
        assert_eq!(
            resumed.pruned, baseline.pruned,
            "stable pruned: crash at event {idx}: pruned counter drifted"
        );
    }
    std::fs::remove_file(&full_path).ok();
    std::fs::remove_file(&case_path).ok();
}

/// Satellite: journal I/O fault injection at *every* append site, for both
/// fault kinds and both `--journal-on-error` policies. fail-stop must
/// abort with a structured cause while leaving a readable journal prefix
/// on disk; degrade must finish the run, flag the result, and match the
/// un-journaled baseline exactly.
#[test]
fn journal_fault_injection_at_every_append_site() {
    let space = svm_space();
    let cfg = TunerConfig {
        optimizer: OptimizerKind::Random,
        num_iterations: 3,
        batch_size: 2,
        backend: SurrogateBackend::Native,
        seed: 2,
        ..Default::default()
    };
    let baseline = Tuner::new(space.clone(), cfg.clone()).maximize(quad).unwrap();

    // A clean journaled run tells us how many appends the run performs
    // (every line after the header is one append).
    let full_path = tmp("fault_full");
    Tuner::new(space.clone(), cfg.clone()).with_journal(&full_path).maximize(quad).unwrap();
    let appends = event_boundaries(&std::fs::read(&full_path).unwrap()).len() - 1;
    assert!(appends >= 6, "expected a rich append stream, got {appends}");
    std::fs::remove_file(&full_path).ok();

    let case_path = tmp("fault_case");
    for k in 0..appends {
        for kind in [JournalFault::Enospc, JournalFault::ShortWrite] {
            // fail-stop (the default): the run aborts with the cause.
            let err = Tuner::new(space.clone(), cfg.clone())
                .with_journal(&case_path)
                .with_journal_fault(k, kind)
                .maximize(quad)
                .unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("journal"), "append {k} {kind:?}: unhelpful error: {msg}");
            // The file on disk is a readable prefix — the reader drops at
            // most the torn trailing line of a short write.
            let prefix = read_journal(&case_path)
                .unwrap_or_else(|e| panic!("append {k} {kind:?}: unreadable prefix: {e:#}"));
            assert!(prefix.events.len() <= appends);

            // degrade: the run finishes without persistence, flags the
            // result, and is byte-identical to the un-journaled baseline.
            let mut degrade_cfg = cfg.clone();
            degrade_cfg.journal_on_error = JournalPolicy::Degrade;
            let r = Tuner::new(space.clone(), degrade_cfg)
                .with_journal(&case_path)
                .with_journal_fault(k, kind)
                .maximize(quad)
                .unwrap_or_else(|e| panic!("append {k} {kind:?}: degrade aborted: {e:#}"));
            assert!(r.journal_degraded, "append {k} {kind:?}: degradation must be flagged");
            assert!(!r.stalled);
            assert_result_eq(&r, &baseline, &format!("degrade at append {k} {kind:?}"));
        }
    }
    std::fs::remove_file(&case_path).ok();
}

// ---------------------------------------------------------------------------
// Segmented journal: rotation, sealing, compaction, and the corpus of crash
// shapes those add. Test names carry `segmented_` / `checkpoint_` /
// `compaction_` / `rotation_` so CI can run exactly this block.
// ---------------------------------------------------------------------------

/// `<base>.seg{idx:06}` — the writer's segment naming scheme.
fn seg_file(base: &Path, idx: u64) -> PathBuf {
    let name = base.file_name().unwrap().to_string_lossy().into_owned();
    base.with_file_name(format!("{name}.seg{idx:06}"))
}

/// Remove the base file and every `<base>.seg*` sibling (segments, staging,
/// quarantine) so reconstructed crash states start from a clean slate.
fn remove_run_files(base: &Path) {
    std::fs::remove_file(base).ok();
    let name = base.file_name().unwrap().to_string_lossy().into_owned();
    let prefix = format!("{name}.seg");
    let Some(dir) = base.parent() else { return };
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for e in entries.flatten() {
        if e.file_name().to_string_lossy().starts_with(&prefix) {
            std::fs::remove_file(e.path()).ok();
        }
    }
}

/// The live segment files of `base` as `(index, bytes)`, ascending — the
/// same exact-6-digit-suffix rule the reader uses, so `.tmp` staging and
/// `.quarantined` files are excluded.
fn live_segments(base: &Path) -> Vec<(u64, Vec<u8>)> {
    let name = base.file_name().unwrap().to_string_lossy().into_owned();
    let prefix = format!("{name}.seg");
    let mut out = Vec::new();
    for e in std::fs::read_dir(base.parent().unwrap()).unwrap().flatten() {
        let fname = e.file_name().to_string_lossy().into_owned();
        if let Some(suffix) = fname.strip_prefix(&prefix) {
            if suffix.len() == 6 && suffix.bytes().all(|b| b.is_ascii_digit()) {
                out.push((suffix.parse().unwrap(), std::fs::read(e.path()).unwrap()));
            }
        }
    }
    out.sort_by_key(|&(idx, _)| idx);
    out
}

/// The segmented flavor of the acceptance-criterion harness. The journaled
/// run keeps every segment (`keep_segments` absurdly high, so live
/// compaction never fires) — every historical disk state of the run is
/// then a *prefix of the files left behind*, and the sweep reconstructs
/// "killed after event k" for every k in every segment. That includes the
/// mid-rotation shapes: sealed newest segment with no successor (crash
/// between seal and create), an embryonic zero-byte successor (crash
/// between create and header write), and a header-only successor. Torn
/// tails — a half-written event line in the active segment and a
/// half-written *seal* line — are exercised on top.
fn segmented_crash_at_every_boundary_with(
    cfg: TunerConfig,
    objective: fn(&Config) -> Option<f64>,
    segment_events: usize,
    label: &str,
) {
    let space = svm_space();

    // Baseline: un-journaled uninterrupted run.
    let baseline = Tuner::new(space.clone(), cfg.clone()).maximize(objective).unwrap();

    // Segmented journaling must be transparent.
    let mut seg_cfg = cfg;
    seg_cfg.journal_segment_events = segment_events;
    seg_cfg.journal_keep_segments = 1000;
    let full_path = tmp(&format!("{label}_full"));
    remove_run_files(&full_path);
    let journaled = Tuner::new(space.clone(), seg_cfg)
        .with_journal(&full_path)
        .maximize(objective)
        .unwrap();
    assert_result_eq(&journaled, &baseline, &format!("{label}: segmentation changed the run"));
    assert!(
        !full_path.exists(),
        "{label}: the segmented layout must not leave a base-path file"
    );

    let segs = live_segments(&full_path);
    assert!(segs.len() >= 3, "{label}: expected >= 3 segments, got {}", segs.len());

    let case_path = tmp(&format!("{label}_case"));
    let restore_prefix = |upto: usize| {
        remove_run_files(&case_path);
        for (idx, bytes) in &segs[..upto] {
            std::fs::write(seg_file(&case_path, *idx), bytes).unwrap();
        }
    };

    for i in 0..segs.len() {
        let (idx, bytes) = &segs[i];
        let mut cuts = event_boundaries(bytes);
        if *idx > 0 {
            // Crash between successor creation and its header write: the
            // newest segment exists but is empty (embryonic).
            cuts.insert(0, 0);
        }
        for (ci, &cut) in cuts.iter().enumerate() {
            restore_prefix(i);
            std::fs::write(seg_file(&case_path, *idx), &bytes[..cut]).unwrap();
            let context = format!("{label}: crash in segment {idx} at boundary {ci}");
            let resumed = Tuner::resume_from(space.clone(), &case_path)
                .unwrap_or_else(|e| panic!("{context}: resume failed: {e:#}"))
                .maximize(objective)
                .unwrap_or_else(|e| panic!("{context}: resumed run failed: {e:#}"));
            assert_result_eq(&resumed, &baseline, &context);
        }
    }

    // A torn half-written event line in the active segment changes nothing.
    let (last_idx, last_bytes) = segs.last().unwrap();
    let lb = event_boundaries(last_bytes);
    let mid = lb[lb.len() / 2];
    restore_prefix(segs.len() - 1);
    let mut torn = last_bytes[..mid].to_vec();
    torn.extend_from_slice(br#"{"e":"sync_eval","iter":9,"conf"#);
    std::fs::write(seg_file(&case_path, *last_idx), &torn).unwrap();
    let resumed = Tuner::resume_from(space.clone(), &case_path)
        .unwrap()
        .maximize(objective)
        .unwrap();
    assert_result_eq(&resumed, &baseline, &format!("{label}: torn trailing event line"));

    // A torn *seal* line: the crash landed mid-rotation, half-way through
    // the seal append. The segment reads back unsealed (the torn tail is
    // the newest segment's one tolerated torn line) and resume re-seals it.
    let (seal_idx, seal_bytes) = &segs[1];
    let sb = event_boundaries(seal_bytes);
    let seal_start = sb[sb.len() - 2];
    let half_seal = seal_start + (seal_bytes.len() - seal_start) / 2;
    restore_prefix(1);
    std::fs::write(seg_file(&case_path, *seal_idx), &seal_bytes[..half_seal]).unwrap();
    let resumed = Tuner::resume_from(space.clone(), &case_path)
        .unwrap()
        .maximize(objective)
        .unwrap();
    assert_result_eq(&resumed, &baseline, &format!("{label}: torn seal line"));

    remove_run_files(&full_path);
    remove_run_files(&case_path);
}

/// Tentpole acceptance criterion, segmented: crash at every event boundary
/// of every segment — including the mid-rotation shapes — and resume to the
/// uninterrupted result, sync mode.
#[test]
fn segmented_sync_crash_at_any_point_resumes_to_identical_result() {
    segmented_crash_at_every_boundary_with(base_config(ExecutionMode::Sync), quad, 4, "seg_sync");
}

/// Same sweep, async event loop.
#[test]
fn segmented_async_crash_at_any_point_resumes_to_identical_result() {
    segmented_crash_at_every_boundary_with(
        base_config(ExecutionMode::Async),
        quad,
        4,
        "seg_async",
    );
}

/// Same sweep under `--replay stable` on the threaded scheduler with a
/// wall-clock-jittered objective: rotation points interleave with
/// nondeterministic completion arrival, and the canonical fold still
/// reproduces the seed-matched run from every reconstructed crash state.
#[test]
fn segmented_stable_threaded_crash_at_any_point_resumes_to_identical_result() {
    segmented_crash_at_every_boundary_with(
        stable_config(SchedulerKind::Threaded, 4),
        jittery_quad,
        5,
        "seg_stable_threaded",
    );
}

/// Same sweep, celery-sim flavor with stragglers.
#[test]
fn segmented_stable_celery_crash_at_any_point_resumes_to_identical_result() {
    let mut cfg = stable_config(SchedulerKind::Celery, 3);
    cfg.celery = Some(CelerySimConfig {
        workers: 3,
        base_latency_ms: 0.3,
        straggler_prob: 0.4,
        straggler_factor: 4.0,
        crash_prob: 0.0,
        result_timeout: Duration::from_secs(10),
    });
    segmented_crash_at_every_boundary_with(cfg, quad, 5, "seg_stable_celery");
}

/// Tentpole acceptance criterion: `--journal-segment-events 0` (the
/// default) keeps the single-file layout — one file at the base path, no
/// segment siblings, readable by the plain reader — and the crash/resume
/// contract is untouched. Compaction never applies to a single-file
/// journal, even when asked for explicitly.
#[test]
fn segmented_zero_segment_events_keeps_the_single_file_layout() {
    assert_eq!(mango::persist::JOURNAL_VERSION, 5);
    let space = svm_space();
    let cfg = base_config(ExecutionMode::Sync); // journal_segment_events: 0
    let baseline = Tuner::new(space.clone(), cfg.clone()).maximize(quad).unwrap();

    let path = tmp("seg_zero");
    remove_run_files(&path);
    let journaled =
        Tuner::new(space.clone(), cfg).with_journal(&path).maximize(quad).unwrap();
    assert_result_eq(&journaled, &baseline, "segment_events=0: journaling changed the run");

    assert!(path.exists(), "segment_events=0 must write the single base file");
    assert!(live_segments(&path).is_empty(), "segment_events=0 must not create segments");
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(
        text.contains(&format!("\"version\":{}", mango::persist::JOURNAL_VERSION)),
        "header must carry the current schema version"
    );
    let stream = read_run(&path).unwrap();
    assert_eq!(stream.layout, JournalLayout::Single);
    assert!(stream.checkpoint.is_none());

    // Explicit compaction of a single-file journal is a no-op, bytes and all.
    let before = std::fs::read(&path).unwrap();
    assert!(!compact(&path, 1).unwrap(), "single-file journals must never compact");
    assert_eq!(std::fs::read(&path).unwrap(), before);

    // And the crash/resume contract is exactly the v4-era one.
    let boundaries = event_boundaries(&before);
    let cut = boundaries[boundaries.len() / 2];
    std::fs::write(&path, &before[..cut]).unwrap();
    let resumed = Tuner::resume_from(space, &path).unwrap().maximize(quad).unwrap();
    assert_result_eq(&resumed, &baseline, "segment_events=0: mid-run crash");
    remove_run_files(&path);
}

/// Satellites 1 + 2: a journal write fault injected at the *rotation*
/// append site (the seal write). fail-stop must abort with the cause and
/// leave a consistent, resumable sealed prefix — the full segment's events
/// with no seal (ENOSPC) or a torn seal line (short write), and crucially
/// *no half-activated successor*. degrade must finish the run flagged,
/// byte-identical to the un-journaled baseline, with the same consistent
/// single-segment disk state.
#[test]
fn rotation_fault_leaves_a_consistent_sealed_prefix() {
    let space = svm_space();
    let mut cfg = base_config(ExecutionMode::Sync);
    cfg.journal_segment_events = 3;
    cfg.journal_keep_segments = 1000;
    let baseline = Tuner::new(space.clone(), cfg.clone()).maximize(quad).unwrap();

    let path = tmp("rotation_fault");
    for kind in [JournalFault::Enospc, JournalFault::ShortWrite] {
        // fail-stop (the default): the run aborts when the first rotation's
        // seal append fails.
        remove_run_files(&path);
        let err = Tuner::new(space.clone(), cfg.clone())
            .with_journal(&path)
            .with_rotation_fault(kind)
            .maximize(quad)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("journal"), "{kind:?}: unhelpful rotation-fault error: {msg}");

        // Disk state: exactly one segment, unsealed, holding the three
        // events that triggered the rotation — no successor was created.
        let segs = live_segments(&path);
        assert_eq!(segs.len(), 1, "{kind:?}: rotation fault must not half-activate a successor");
        assert_eq!(segs[0].0, 0);
        let stream = read_run(&path)
            .unwrap_or_else(|e| panic!("{kind:?}: post-fault journal unreadable: {e:#}"));
        assert_eq!(stream.events.len(), 3, "{kind:?}: the sealed prefix must hold 3 events");
        assert_eq!(
            stream.layout,
            JournalLayout::Segmented {
                active: 0,
                active_sealed: false,
                next_index: 1,
                sealed: vec![],
                stale: vec![],
            },
            "{kind:?}: a torn/absent seal must read back as an unsealed active segment"
        );

        // And that prefix resumes to the uninterrupted result.
        let resumed =
            Tuner::resume_from(space.clone(), &path).unwrap().maximize(quad).unwrap();
        assert_result_eq(&resumed, &baseline, &format!("rotation fault {kind:?}"));

        // degrade: the run survives the rotation fault without persistence.
        remove_run_files(&path);
        let mut degrade_cfg = cfg.clone();
        degrade_cfg.journal_on_error = JournalPolicy::Degrade;
        let r = Tuner::new(space.clone(), degrade_cfg)
            .with_journal(&path)
            .with_rotation_fault(kind)
            .maximize(quad)
            .unwrap_or_else(|e| panic!("{kind:?}: degrade aborted: {e:#}"));
        assert!(r.journal_degraded, "{kind:?}: degradation must be flagged");
        assert_result_eq(&r, &baseline, &format!("degrade at rotation {kind:?}"));
        let segs = live_segments(&path);
        assert_eq!(segs.len(), 1, "{kind:?}: degrade must leave a consistent sealed prefix");
        assert!(read_run(&path).is_ok(), "{kind:?}: the degraded prefix must stay readable");
    }
    remove_run_files(&path);
}

/// Tentpole: a sealed segment whose bytes rot is *corruption*, not a torn
/// tail — fail-stop refuses loudly on the checksum, and a sealed segment
/// that lost its seal line entirely (yet is not the newest) is refused
/// too. Under `--journal-on-error degrade` (journaled in the header) the
/// bad segment and everything after it are quarantined and the run resumes
/// from the intact sealed prefix.
#[test]
fn segmented_corrupt_sealed_segment_fails_loudly_and_quarantines_under_degrade() {
    let space = svm_space();
    let mut cfg = base_config(ExecutionMode::Sync);
    cfg.journal_segment_events = 3;
    cfg.journal_keep_segments = 1000;
    let baseline = Tuner::new(space.clone(), cfg.clone()).maximize(quad).unwrap();

    // Flip one hex digit of a seal's crc field.
    let corrupt_crc = |bytes: &[u8]| -> Vec<u8> {
        let text = String::from_utf8(bytes.to_vec()).unwrap();
        let at = text.rfind("\"crc\":\"").expect("sealed segment must carry a crc") + 7;
        let mut out = text.into_bytes();
        out[at] = if out[at] == b'0' { b'1' } else { b'0' };
        out
    };

    for degrade in [false, true] {
        let mut run_cfg = cfg.clone();
        if degrade {
            run_cfg.journal_on_error = JournalPolicy::Degrade;
        }
        let path = tmp(if degrade { "seg_corrupt_degrade" } else { "seg_corrupt" });
        remove_run_files(&path);
        Tuner::new(space.clone(), run_cfg).with_journal(&path).maximize(quad).unwrap();
        let segs = live_segments(&path);
        assert!(segs.len() >= 3, "need a sealed middle segment, got {}", segs.len());
        let (bad_idx, bad_bytes) = &segs[1];
        std::fs::write(seg_file(&path, *bad_idx), corrupt_crc(bad_bytes)).unwrap();

        if degrade {
            // Quarantine + resume from the sealed prefix below the damage.
            let resumed = Tuner::resume_from(space.clone(), &path)
                .unwrap_or_else(|e| panic!("degrade must quarantine, not refuse: {e:#}"))
                .maximize(quad)
                .unwrap();
            assert_result_eq(&resumed, &baseline, "resume from quarantined journal");
            let quarantined =
                PathBuf::from(format!("{}.quarantined", seg_file(&path, *bad_idx).display()));
            assert!(quarantined.exists(), "the corrupt segment must be quarantined, not lost");
        } else {
            let err = Tuner::resume_from(space.clone(), &path).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("checksum mismatch"), "got: {msg}");

            // A sealed non-newest segment with its seal line chopped off is
            // equally corrupt: rotations never complete without sealing.
            std::fs::write(seg_file(&path, *bad_idx), bad_bytes).unwrap();
            let sb = event_boundaries(bad_bytes);
            std::fs::write(seg_file(&path, *bad_idx), &bad_bytes[..sb[sb.len() - 2]]).unwrap();
            let err = Tuner::resume_from(space.clone(), &path).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("no seal"), "got: {msg}");
        }
        remove_run_files(&path);
    }
}

/// Tentpole: live compaction during a run bounds the disk footprint to
/// O(active window) — checkpoint segment + kept sealed tail + active —
/// while staying invisible to the trajectory, and a crash in the active
/// segment resumes from (checkpoint + tail segments) to the identical
/// result.
#[test]
fn compaction_during_run_bounds_live_segments_and_resumes_from_checkpoint_plus_tail() {
    let space = svm_space();
    let cfg = base_config(ExecutionMode::Async);
    let baseline = Tuner::new(space.clone(), cfg.clone()).maximize(quad).unwrap();

    let mut seg_cfg = cfg;
    seg_cfg.journal_segment_events = 3;
    seg_cfg.journal_keep_segments = 1;
    let path = tmp("compaction_live");
    remove_run_files(&path);
    let journaled =
        Tuner::new(space.clone(), seg_cfg).with_journal(&path).maximize(quad).unwrap();
    assert_result_eq(&journaled, &baseline, "live compaction changed the run");

    let segs = live_segments(&path);
    assert!(
        segs.len() <= 3,
        "keep=1 steady state is checkpoint + 1 sealed + active, got {} segments",
        segs.len()
    );
    let stream = read_run(&path).unwrap();
    let cp = stream.checkpoint.expect("a run this long must have compacted");
    assert!(cp.covers >= 1, "the checkpoint must actually cover folded segments");

    // Resume from the finished compacted journal: pure replay, same result.
    let resumed = Tuner::resume_from(space.clone(), &path).unwrap().maximize(quad).unwrap();
    assert_result_eq(&resumed, &baseline, "resume from finished compacted journal");

    // Crash at every boundary of the *active* segment: resume folds the
    // checkpoint, replays the kept sealed tail, and re-runs the rest.
    let segs = live_segments(&path);
    let (active_idx, active_bytes) = segs.last().unwrap().clone();
    for (ci, &cut) in event_boundaries(&active_bytes).iter().enumerate() {
        remove_run_files(&path);
        for (idx, bytes) in &segs[..segs.len() - 1] {
            std::fs::write(seg_file(&path, *idx), bytes).unwrap();
        }
        std::fs::write(seg_file(&path, active_idx), &active_bytes[..cut]).unwrap();
        let context = format!("checkpoint+tail crash at active boundary {ci}");
        let resumed = Tuner::resume_from(space.clone(), &path)
            .unwrap_or_else(|e| panic!("{context}: resume failed: {e:#}"))
            .maximize(quad)
            .unwrap_or_else(|e| panic!("{context}: resumed run failed: {e:#}"));
        assert_result_eq(&resumed, &baseline, &context);
    }
    remove_run_files(&path);
}

/// Tentpole: the two crash windows *inside* compaction itself. (a) Crash
/// before the atomic rename: a stray staging file sits next to the intact
/// segments — reads ignore it, resume deletes it. (b) Crash after the
/// rename but before the covered segments are deleted: checkpoint and
/// covered segments coexist — reads skip the stale segments (their events
/// are already folded), resume deletes them. In every state the recovered
/// replay is bit-identical to the uncompacted stream's.
#[test]
fn compaction_crash_states_replay_identically_and_are_cleaned_on_resume() {
    let space = svm_space();
    let mut cfg = base_config(ExecutionMode::Sync);
    cfg.journal_segment_events = 3;
    cfg.journal_keep_segments = 1000; // no live compaction: we drive it by hand
    let baseline = Tuner::new(space.clone(), cfg.clone()).maximize(quad).unwrap();

    let path = tmp("compaction_crash");
    remove_run_files(&path);
    Tuner::new(space.clone(), cfg).with_journal(&path).maximize(quad).unwrap();
    let segs = live_segments(&path);
    assert!(segs.len() >= 5, "need enough segments to fold, got {}", segs.len());
    let full_replay = recover(&path).unwrap().replay;

    // (a) Crash before the rename: only the staging file exists.
    let staging = PathBuf::from(format!("{}.tmp", seg_file(&path, 0).display()));
    std::fs::write(&staging, b"half-written checkpoint garbage").unwrap();
    assert_eq!(
        recover(&path).unwrap().replay,
        full_replay,
        "a stray staging file must not perturb recovery"
    );
    let resumed = Tuner::resume_from(space.clone(), &path).unwrap().maximize(quad).unwrap();
    assert_result_eq(&resumed, &baseline, "resume over a stray staging file");
    assert!(!staging.exists(), "resume must clean up crashed-compaction staging");

    // Restore the pristine uncompacted layout, then compact for real.
    remove_run_files(&path);
    for (idx, bytes) in &segs {
        std::fs::write(seg_file(&path, *idx), bytes).unwrap();
    }
    assert!(compact(&path, 1).unwrap(), "explicit compaction must fire");
    let stream = read_run(&path).unwrap();
    let covers = stream.checkpoint.as_ref().expect("compaction must leave a checkpoint").covers;
    assert!(covers >= 2);
    assert_eq!(
        recover(&path).unwrap().replay,
        full_replay,
        "recover(checkpoint + tail) must bit-equal recover(full event stream)"
    );

    // (b) Crash after the rename: resurrect the covered segments compaction
    // had deleted. They are stale — skipped on read, deleted on resume.
    for (idx, bytes) in &segs {
        if *idx >= 1 && *idx <= covers {
            std::fs::write(seg_file(&path, *idx), bytes).unwrap();
        }
    }
    assert_eq!(
        recover(&path).unwrap().replay,
        full_replay,
        "checkpoint-covered leftovers must not be double-folded"
    );
    let resumed = Tuner::resume_from(space.clone(), &path).unwrap().maximize(quad).unwrap();
    assert_result_eq(&resumed, &baseline, "resume over checkpoint-covered leftovers");
    for idx in 1..=covers {
        assert!(
            !seg_file(&path, idx).exists(),
            "resume must delete stale segment {idx}"
        );
    }
    remove_run_files(&path);
}

/// Satellite: `--compact-on-resume` folds the sealed prefix into one
/// checkpoint *before* reopening the journal — the resumed run matches the
/// uninterrupted one and the disk footprint shrinks to checkpoint + kept
/// tail + active.
#[test]
fn compaction_on_resume_shrinks_the_journal_to_checkpoint_plus_tail() {
    let space = svm_space();
    let cfg = base_config(ExecutionMode::Sync);
    let baseline = Tuner::new(space.clone(), cfg.clone()).maximize(quad).unwrap();

    let mut seg_cfg = cfg;
    seg_cfg.journal_segment_events = 3;
    seg_cfg.journal_keep_segments = 1000; // the run itself never compacts
    let path = tmp("compaction_on_resume");
    remove_run_files(&path);
    Tuner::new(space.clone(), seg_cfg).with_journal(&path).maximize(quad).unwrap();
    let segs = live_segments(&path);
    assert!(segs.len() >= 5, "need an uncompacted pile of segments, got {}", segs.len());

    // Crash mid-way through the active segment, then resume with
    // compaction requested and a tighter retention override.
    let (active_idx, active_bytes) = segs.last().unwrap();
    let ab = event_boundaries(active_bytes);
    std::fs::write(seg_file(&path, *active_idx), &active_bytes[..ab[ab.len() / 2]]).unwrap();
    let resumed = Tuner::resume_from(space.clone(), &path)
        .unwrap()
        .with_keep_segments(1)
        .with_compact_on_resume(true)
        .maximize(quad)
        .unwrap();
    assert_result_eq(&resumed, &baseline, "compact-on-resume");
    assert!(
        read_run(&path).unwrap().checkpoint.is_some(),
        "resume-time compaction must leave a checkpoint"
    );
    assert!(
        live_segments(&path).len() <= 3,
        "keep=1 after compact-on-resume is checkpoint + 1 sealed + active, got {}",
        live_segments(&path).len()
    );
    remove_run_files(&path);
}

/// Satellite: the GP Cholesky factor rehydrated from a *compacted* journal
/// is bit-identical to the one rehydrated from the full event stream, and
/// both match a scratch fit over the same rows — the checkpoint codec
/// loses nothing the surrogate can see.
#[test]
fn checkpoint_rehydrated_cholesky_factor_is_bit_identical_through_compaction() {
    let space = svm_space();
    let mut cfg = base_config(ExecutionMode::Async);
    cfg.journal_segment_events = 3;
    cfg.journal_keep_segments = 1000;
    let path = tmp("checkpoint_cholesky");
    remove_run_files(&path);
    Tuner::new(space.clone(), cfg).with_journal(&path).maximize(quad).unwrap();

    let full_replay = recover(&path).unwrap().replay;
    assert!(compact(&path, 1).unwrap(), "compaction must fire");
    let compact_replay = recover(&path).unwrap().replay;
    assert_eq!(
        full_replay, compact_replay,
        "the replay folded through a checkpoint must bit-equal the full-stream fold"
    );

    let (Replay::Async(full), Replay::Async(folded)) = (&full_replay, &compact_replay) else {
        panic!("async run must recover an async replay");
    };
    let rehydrated = |rows: &[(Config, f64)], rounds: usize| {
        let opts = GpOptions {
            backend: SurrogateBackend::Native,
            fixed_beta: Some(2.0),
            ..Default::default()
        };
        let mut history = History::new();
        for (c, v) in rows {
            history.push(c.clone(), *v);
        }
        let mut core = BayesianCore::new(space.clone(), opts).unwrap();
        core.rehydrate(&history, rounds).unwrap();
        core
    };
    let a = rehydrated(&full.history, full.rounds);
    let b = rehydrated(&folded.history, folded.rounds);
    let d = Encoder::new(&space).dims();
    let mut params = GpParams::new(d);
    params.noise = 1e-3; // GpOptions::default().noise
    let fa = a.cached_state(&params).expect("full-stream rehydration must cache a state");
    let fb = b.cached_state(&params).expect("checkpoint rehydration must cache a state");
    assert_eq!(
        fa.factor(),
        fb.factor(),
        "Cholesky factor must be bit-identical through a compaction"
    );

    // Ground truth: a scratch fit over the same rows.
    let encoder = Encoder::new(&space);
    let configs: Vec<Config> = full.history.iter().map(|(c, _)| c.clone()).collect();
    let flat = encoder.encode_batch(&configs);
    let x = Matrix::from_vec(configs.len(), d, flat);
    let y = vec![0.0; configs.len()]; // y never enters the factor
    let (truth, _) = fit_posterior(&x, &y, &params, None).unwrap();
    assert_eq!(fb.factor(), &truth.chol, "factor must match a scratch fit exactly");
    remove_run_files(&path);
}

/// Satellite: compaction folds `Pruned` terminals and intermediate-report
/// state losslessly — stable replay, threaded scheduler, median pruner.
/// The compacted journal's replay bit-equals the full stream's, and a
/// crash in the active segment resumes to the uninterrupted result with
/// the pruning counters intact.
#[test]
fn segmented_compaction_preserves_pruned_trials_on_stable_threaded() {
    let space = svm_space();
    let mut cfg = stable_config(SchedulerKind::Threaded, 4);
    cfg.pruner = PrunerKind::Median;
    cfg.pruner_warmup = 1;
    let staged = |cfg: &Config, reporter: &TrialReporter| {
        std::thread::sleep(Duration::from_millis(cfg.get_f64("c")? as u64 % 4));
        staged_quad(cfg, reporter)
    };
    let baseline =
        Tuner::new(space.clone(), cfg.clone()).maximize_with_reports(staged).unwrap();
    assert!(baseline.pruned >= 1, "the staged workload must actually prune");

    cfg.journal_segment_events = 4;
    cfg.journal_keep_segments = 1000;
    let path = tmp("seg_pruned");
    remove_run_files(&path);
    let journaled = Tuner::new(space.clone(), cfg)
        .with_journal(&path)
        .maximize_with_reports(staged)
        .unwrap();
    assert_result_eq(&journaled, &baseline, "segmented pruned: journaling changed the run");
    assert_eq!(journaled.pruned, baseline.pruned, "segmented pruned: counter drifted");

    let full_replay = recover(&path).unwrap().replay;
    assert!(compact(&path, 1).unwrap(), "compaction must fire");
    assert_eq!(
        recover(&path).unwrap().replay,
        full_replay,
        "pruned/report state must fold through the checkpoint bit-exactly"
    );

    let segs = live_segments(&path);
    let (active_idx, active_bytes) = segs.last().unwrap().clone();
    for (ci, &cut) in event_boundaries(&active_bytes).iter().enumerate() {
        remove_run_files(&path);
        for (idx, bytes) in &segs[..segs.len() - 1] {
            std::fs::write(seg_file(&path, *idx), bytes).unwrap();
        }
        std::fs::write(seg_file(&path, active_idx), &active_bytes[..cut]).unwrap();
        let context = format!("segmented pruned: crash at active boundary {ci}");
        let resumed = Tuner::resume_from(space.clone(), &path)
            .unwrap_or_else(|e| panic!("{context}: resume failed: {e:#}"))
            .maximize_with_reports(staged)
            .unwrap_or_else(|e| panic!("{context}: resumed run failed: {e:#}"));
        assert_result_eq(&resumed, &baseline, &context);
        assert_eq!(resumed.pruned, baseline.pruned, "{context}: pruned counter drifted");
    }
    remove_run_files(&path);
}

/// Satellite: compaction folds `Lost`/`Resubmitted` terminals and the
/// journaled retry schedule losslessly — stable replay on a faulty
/// celery-sim cluster. Replay equality through the checkpoint, plus the
/// active-segment crash sweep with the retry counter intact.
#[test]
fn segmented_compaction_preserves_lost_trials_on_stable_celery() {
    let space = svm_space();
    let mut cfg = TunerConfig {
        optimizer: OptimizerKind::Random,
        num_iterations: 7,
        batch_size: 2,
        backend: SurrogateBackend::Native,
        scheduler: SchedulerKind::Celery,
        workers: 3,
        max_retries: 2,
        retry_backoff_ms: 2.0,
        seed: 21,
        mode: ExecutionMode::Async,
        replay: ReplayMode::Stable,
        celery: Some(CelerySimConfig {
            workers: 3,
            base_latency_ms: 0.3,
            straggler_prob: 0.0,
            straggler_factor: 1.0,
            crash_prob: 0.4,
            result_timeout: Duration::from_secs(10),
        }),
        ..Default::default()
    };
    let baseline = Tuner::new(space.clone(), cfg.clone()).maximize(quad).unwrap();
    assert!(baseline.retried > 0, "crash_prob 0.4 must trigger retries (got none)");

    cfg.journal_segment_events = 4;
    cfg.journal_keep_segments = 1000;
    let path = tmp("seg_lost");
    remove_run_files(&path);
    let journaled =
        Tuner::new(space.clone(), cfg).with_journal(&path).maximize(quad).unwrap();
    assert_result_eq(&journaled, &baseline, "segmented lost: journaling changed the run");
    assert_eq!(journaled.retried, baseline.retried, "segmented lost: retry schedule drifted");

    let full_replay = recover(&path).unwrap().replay;
    assert!(compact(&path, 1).unwrap(), "compaction must fire");
    assert_eq!(
        recover(&path).unwrap().replay,
        full_replay,
        "lost/retry state must fold through the checkpoint bit-exactly"
    );

    let segs = live_segments(&path);
    let (active_idx, active_bytes) = segs.last().unwrap().clone();
    for (ci, &cut) in event_boundaries(&active_bytes).iter().enumerate() {
        remove_run_files(&path);
        for (idx, bytes) in &segs[..segs.len() - 1] {
            std::fs::write(seg_file(&path, *idx), bytes).unwrap();
        }
        std::fs::write(seg_file(&path, active_idx), &active_bytes[..cut]).unwrap();
        let context = format!("segmented lost: crash at active boundary {ci}");
        let resumed = Tuner::resume_from(space.clone(), &path)
            .unwrap_or_else(|e| panic!("{context}: resume failed: {e:#}"))
            .maximize(quad)
            .unwrap_or_else(|e| panic!("{context}: resumed run failed: {e:#}"));
        assert_result_eq(&resumed, &baseline, &context);
        assert_eq!(resumed.retried, baseline.retried, "{context}: retry schedule drifted");
    }
    remove_run_files(&path);
}
