//! Backend parity: the PJRT artifacts (JAX + Pallas, AOT-lowered) must agree
//! with the native-Rust GP on fits and acquisitions — this is the test that
//! proves the three-layer bridge carries correct numerics.

use mango::gp::{normalize_y, GpParams, NativeGp, Surrogate};
use mango::linalg::Matrix;
use mango::runtime::PjrtSurrogate;
use mango::util::rng::Pcg64;

fn toy(n: usize, d: usize, seed: u64) -> (Matrix, Vec<f64>, Matrix) {
    let mut rng = Pcg64::new(seed);
    let x = Matrix::from_fn(n, d, |_, _| rng.next_f64());
    let y: Vec<f64> = (0..n)
        .map(|i| {
            let r = x.row(i);
            (7.0 * r[0]).sin() + 0.3 * r[d.min(1)] - 0.1 * r[0] * r[0]
        })
        .collect();
    let xc = Matrix::from_fn(97, d, |_, _| rng.next_f64()); // non-multiple of 512 chunks
    (x, y, xc)
}

fn parity_case(n: usize, d: usize, seed: u64, tol: f64) {
    let (x, y, xc) = toy(n, d, seed);
    let (yn, _, _) = normalize_y(&y);
    let params = GpParams::new(d);

    let mut native = NativeGp;
    let fit_n = native.fit(&x, &yn, &params).unwrap();
    let acq_n = native.acquire(&x, &fit_n, &xc, &params).unwrap();

    let mut pjrt = PjrtSurrogate::from_default_artifacts().expect("artifacts built?");
    let fit_p = pjrt.fit(&x, &yn, &params).unwrap();
    let acq_p = pjrt.acquire(&x, &fit_p, &xc, &params).unwrap();

    for i in 0..n {
        assert!(
            (fit_n.alpha[i] - fit_p.alpha[i]).abs() < tol * 10.0,
            "alpha[{i}]: native {} vs pjrt {}",
            fit_n.alpha[i],
            fit_p.alpha[i]
        );
    }
    assert!(
        (fit_n.logdet - fit_p.logdet).abs() < 0.05 * fit_n.logdet.abs().max(1.0),
        "logdet: native {} vs pjrt {}",
        fit_n.logdet,
        fit_p.logdet
    );
    for c in 0..xc.rows() {
        assert!(
            (acq_n.mean[c] - acq_p.mean[c]).abs() < tol,
            "mean[{c}]: {} vs {}",
            acq_n.mean[c],
            acq_p.mean[c]
        );
        assert!(
            (acq_n.var[c] - acq_p.var[c]).abs() < tol,
            "var[{c}]: {} vs {}",
            acq_n.var[c],
            acq_p.var[c]
        );
        assert!(
            (acq_n.ucb[c] - acq_p.ucb[c]).abs() < tol * 3.0,
            "ucb[{c}]: {} vs {}",
            acq_n.ucb[c],
            acq_p.ucb[c]
        );
    }
}

#[test]
fn parity_small() {
    parity_case(10, 3, 1, 2e-3);
}

#[test]
fn parity_medium_fills_variant() {
    parity_case(64, 7, 2, 2e-3); // exactly the n=64 variant
}

#[test]
fn parity_crosses_variant_boundary() {
    parity_case(65, 7, 3, 2e-3); // must pick the n=128 variant
}

#[test]
fn parity_large_chunked_candidates() {
    // Candidate count > m_cand to exercise the chunking path.
    let (x, y, _) = toy(40, 5, 4);
    let (yn, _, _) = normalize_y(&y);
    let params = GpParams::new(5);
    let mut rng = Pcg64::new(99);
    let xc = Matrix::from_fn(1200, 5, |_, _| rng.next_f64());

    let mut native = NativeGp;
    let fit_n = native.fit(&x, &yn, &params).unwrap();
    let acq_n = native.acquire(&x, &fit_n, &xc, &params).unwrap();

    let mut pjrt = PjrtSurrogate::from_default_artifacts().unwrap();
    let fit_p = pjrt.fit(&x, &yn, &params).unwrap();
    let acq_p = pjrt.acquire(&x, &fit_p, &xc, &params).unwrap();
    assert!(pjrt.acquire_calls >= 3, "1200 candidates need >= 3 chunks");

    for c in 0..1200 {
        assert!((acq_n.ucb[c] - acq_p.ucb[c]).abs() < 5e-3);
    }
}

#[test]
fn incremental_fit_parity_and_capacity_contract() {
    // The PJRT backend must serve the incremental-fit contract: a fit that
    // reuses a CholeskyState over a prefix of the history must score
    // candidates identically (within backend tolerance) to a native
    // from-scratch fit, and max_obs must answer from the backend (manifest
    // capacity or the fallback default), not a hardcoded mirror.
    let (x, y, xc) = toy(40, 4, 11);
    let (yn, _, _) = normalize_y(&y);
    let params = GpParams::new(4);

    let mut pjrt = PjrtSurrogate::from_default_artifacts().unwrap();
    assert!(Surrogate::max_obs(&pjrt) >= 128, "artifact capacity too small");

    let x0 = Matrix::from_fn(30, 4, |i, j| x[(i, j)]);
    let (_, state) = pjrt.fit_incremental(&x0, &yn[..30], &params, None).unwrap();
    let (fit_inc, state) = pjrt.fit_incremental(&x, &yn, &params, Some(state)).unwrap();
    assert_eq!(state.rows(), 40);
    let acq_inc = pjrt.acquire(&x, &fit_inc, &xc, &params).unwrap();

    let mut native = NativeGp;
    let fit_n = native.fit(&x, &yn, &params).unwrap();
    let acq_n = native.acquire(&x, &fit_n, &xc, &params).unwrap();

    for c in 0..xc.rows() {
        assert!(
            (acq_inc.mean[c] - acq_n.mean[c]).abs() < 2e-3,
            "mean[{c}]: {} vs {}",
            acq_inc.mean[c],
            acq_n.mean[c]
        );
        assert!(
            (acq_inc.var[c] - acq_n.var[c]).abs() < 2e-3,
            "var[{c}]: {} vs {}",
            acq_inc.var[c],
            acq_n.var[c]
        );
    }
}

#[test]
fn w_matrix_parity_supports_hallucination() {
    // The w output feeds BatchHallucinator; verify cross-backend agreement
    // and that hallucination on PJRT outputs matches native hallucination.
    use mango::gp::update::BatchHallucinator;
    let (x, y, xc) = toy(30, 4, 7);
    let (yn, _, _) = normalize_y(&y);
    let params = GpParams::new(4);

    let mut native = NativeGp;
    let fit_n = native.fit(&x, &yn, &params).unwrap();
    let acq_n = native.acquire(&x, &fit_n, &xc, &params).unwrap();

    let mut pjrt = PjrtSurrogate::from_default_artifacts().unwrap();
    let fit_p = pjrt.fit(&x, &yn, &params).unwrap();
    let acq_p = pjrt.acquire(&x, &fit_p, &xc, &params).unwrap();

    let mut hn = BatchHallucinator::new(&x, &xc, &acq_n, &params);
    let mut hp = BatchHallucinator::new(&x, &xc, &acq_p, &params);
    for step in 0..5 {
        let bn = hn.select_next().unwrap();
        let bp = hp.select_next().unwrap();
        assert_eq!(bn, bp, "step {step}: backends picked different candidates");
    }
}
