//! End-to-end coordinator tests over the real PJRT artifacts: full tuning
//! runs exercising optimizer + scheduler + runtime together, in both the
//! batch-synchronous and the async event-loop coordination modes.

use mango::coordinator::{ExecutionMode, Tuner, TunerConfig};
use mango::exp::workloads;
use mango::optimizer::{OptimizerKind, SurrogateBackend};
use mango::scheduler::celery::{CelerySimConfig, CelerySimScheduler};
use mango::scheduler::{Scheduler, SchedulerKind};

fn base(kind: OptimizerKind, iters: usize, batch: usize, seed: u64) -> TunerConfig {
    TunerConfig {
        optimizer: kind,
        num_iterations: iters,
        batch_size: batch,
        backend: SurrogateBackend::Pjrt,
        seed,
        ..Default::default()
    }
}

#[test]
fn pjrt_tuner_beats_random_on_branin() {
    let workload = workloads::by_name("branin").unwrap();
    let run = |kind: OptimizerKind, seed: u64| {
        let mut tuner = Tuner::new(workload.space.clone(), base(kind, 25, 1, seed));
        let obj = workload.objective.clone();
        tuner.minimize(move |c| obj(c)).unwrap().best_objective
    };
    let seeds = [1u64, 2, 3];
    let gp: f64 =
        seeds.iter().map(|&s| run(OptimizerKind::Hallucination, s)).sum::<f64>() / 3.0;
    let rnd: f64 = seeds.iter().map(|&s| run(OptimizerKind::Random, s)).sum::<f64>() / 3.0;
    assert!(
        gp < rnd + 0.5,
        "GP-UCB ({gp:.3}) should at least match random ({rnd:.3}) on 25 evals"
    );
    assert!(gp < 2.5, "GP-UCB should get close to the optimum, got {gp:.3}");
}

#[test]
fn history_crosses_artifact_variant_boundary() {
    // 70 serial iterations -> 70 observations: the surrogate must switch
    // from the n=64 variant to n=128 mid-run without a hiccup.
    let workload = workloads::by_name("branin").unwrap();
    let mut tuner = Tuner::new(
        workload.space.clone(),
        base(OptimizerKind::Hallucination, 70, 1, 9),
    );
    let obj = workload.objective.clone();
    let result = tuner.minimize(move |c| obj(c)).unwrap();
    assert_eq!(result.evaluations, 70);
    assert!(result.best_objective < 3.0);
}

#[test]
fn parallel_batches_run_on_threaded_scheduler() {
    let workload = workloads::by_name("mixed_branin").unwrap();
    let mut cfg = base(OptimizerKind::Clustering, 12, 5, 3);
    cfg.scheduler = SchedulerKind::Threaded;
    cfg.workers = 5;
    let mut tuner = Tuner::new(workload.space.clone(), cfg);
    let obj = workload.objective.clone();
    let result = tuner.minimize(move |c| obj(c)).unwrap();
    assert_eq!(result.evaluations, 60);
    assert!(result.best_objective < 6.0);
}

#[test]
fn faulty_celery_cluster_still_converges() {
    // A lossy cluster must produce partial results and a usable optimum.
    let workload = workloads::by_name("branin").unwrap();
    let cluster = CelerySimConfig {
        workers: 4,
        base_latency_ms: 0.5,
        straggler_prob: 0.1,
        straggler_factor: 5.0,
        crash_prob: 0.25,
        result_timeout: std::time::Duration::from_millis(400),
    };
    let mut sched = CelerySimScheduler::new(cluster, 11);
    let mut tuner = Tuner::new(
        workload.space.clone(),
        base(OptimizerKind::Hallucination, 20, 5, 13),
    );
    let obj = workload.objective.clone();
    let result = tuner
        .maximize_batch(|batch| {
            // negate: maximize_batch with -f == minimize f
            let mut r = sched.evaluate(&|c| obj(c).map(|v| -v), batch);
            r.evals.iter_mut().for_each(|_| {});
            r
        })
        .unwrap();
    assert!(sched.stats.crashed > 0, "fault injection must fire");
    assert!(
        result.evaluations < 100 && result.evaluations > 40,
        "partial results expected, got {}",
        result.evaluations
    );
    assert!(-result.best_objective < 3.0, "still converges despite loss");
}

#[test]
fn seeded_runs_reproduce_exactly_on_pjrt() {
    let workload = workloads::by_name("mixed_branin").unwrap();
    let run = || {
        let mut tuner = Tuner::new(
            workload.space.clone(),
            base(OptimizerKind::Hallucination, 10, 2, 77),
        );
        let obj = workload.objective.clone();
        tuner.minimize(move |c| obj(c)).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.best_objective, b.best_objective);
    assert_eq!(a.best_series, b.best_series);
    assert_eq!(a.best_params, b.best_params);
}

#[test]
fn tpe_full_run_on_wine_knn() {
    // Classifier workload end-to-end with the TPE baseline (no GP).
    let workload = workloads::by_name("knn_wine").unwrap();
    let mut cfg = base(OptimizerKind::Tpe, 15, 2, 5);
    cfg.backend = SurrogateBackend::Native; // TPE needs no surrogate at all
    let mut tuner = Tuner::new(workload.space.clone(), cfg);
    let obj = workload.objective.clone();
    let result = tuner.maximize(move |c| obj(c)).unwrap();
    assert!(result.best_objective > 0.90, "kNN tunable to >0.9, got {}", result.best_objective);
}

// ---------------- async event-loop mode ----------------

/// A lossy cluster in async mode: crashes surface as `Lost` events and get
/// retried, so the run recovers evaluations sync mode silently drops —
/// while still converging (the `faulty_celery_cluster_still_converges`
/// invariants ported to the event loop).
#[test]
fn async_faulty_celery_cluster_retries_and_converges() {
    let workload = workloads::by_name("branin").unwrap();
    let mut cfg = base(OptimizerKind::Hallucination, 20, 5, 13);
    cfg.mode = ExecutionMode::Async;
    cfg.scheduler = SchedulerKind::Celery;
    cfg.workers = 4;
    cfg.max_retries = 3;
    cfg.celery = Some(CelerySimConfig {
        workers: 4,
        base_latency_ms: 0.5,
        straggler_prob: 0.1,
        straggler_factor: 5.0,
        crash_prob: 0.25,
        result_timeout: std::time::Duration::from_millis(400),
    });
    let mut tuner = Tuner::new(workload.space.clone(), cfg);
    let obj = workload.objective.clone();
    let result = tuner.minimize(move |c| obj(c)).unwrap();
    let stats = result.scheduler_stats.as_ref().unwrap();
    assert!(stats.lost > 0, "fault injection must fire");
    assert!(result.retried > 0, "lost tasks must be resubmitted");
    // Retries recover most of the budget sync mode would silently drop.
    assert!(
        result.evaluations > 80 && result.evaluations <= 100,
        "retried async run should land close to the 100-eval budget, got {}",
        result.evaluations
    );
    assert!(result.best_objective < 3.0, "still converges despite loss");
}

/// Retry exhaustion: a cluster that loses *everything* must terminate (no
/// spin on eternally-lost work) and report the no-data error.
#[test]
fn async_retry_exhaustion_terminates_with_error() {
    let workload = workloads::by_name("branin").unwrap();
    let mut cfg = base(OptimizerKind::Random, 4, 2, 3);
    cfg.backend = SurrogateBackend::Native;
    cfg.mode = ExecutionMode::Async;
    cfg.scheduler = SchedulerKind::Celery;
    cfg.workers = 2;
    cfg.max_retries = 1;
    cfg.celery = Some(CelerySimConfig {
        workers: 2,
        base_latency_ms: 0.5,
        straggler_prob: 0.0,
        straggler_factor: 1.0,
        crash_prob: 1.0, // every task is lost, every retry too
        result_timeout: std::time::Duration::from_secs(5),
    });
    let mut tuner = Tuner::new(workload.space.clone(), cfg);
    let obj = workload.objective.clone();
    let err = tuner.minimize(move |c| obj(c)).unwrap_err();
    assert!(err.to_string().contains("no evaluation"), "got: {err}");
}

/// Partial-results invariant in async mode with retries disabled: losses
/// reduce the evaluation count, but everything that did arrive is usable
/// (the port of `batch_mode_with_partial_results`).
#[test]
fn async_partial_results_without_retries() {
    let workload = workloads::by_name("branin").unwrap();
    let mut cfg = base(OptimizerKind::Random, 10, 4, 7);
    cfg.backend = SurrogateBackend::Native;
    cfg.mode = ExecutionMode::Async;
    cfg.scheduler = SchedulerKind::Celery;
    cfg.workers = 4;
    cfg.max_retries = 0;
    cfg.celery = Some(CelerySimConfig {
        workers: 4,
        base_latency_ms: 0.5,
        straggler_prob: 0.0,
        straggler_factor: 1.0,
        crash_prob: 0.5,
        result_timeout: std::time::Duration::from_secs(5),
    });
    let mut tuner = Tuner::new(workload.space.clone(), cfg);
    let obj = workload.objective.clone();
    let result = tuner.minimize(move |c| obj(c)).unwrap();
    assert!(result.evaluations < 40, "some of the 40 proposals must be lost");
    assert!(result.evaluations > 0, "but not all");
    assert_eq!(
        result.lost as usize + result.evaluations,
        40,
        "every proposal concludes exactly once: done or lost"
    );
    assert_eq!(result.retried, 0, "retries disabled");
    // best_series has one point per concluded proposal, monotone for
    // minimization in user sense.
    assert_eq!(result.best_series.len(), 40);
    for w in result.best_series.windows(2) {
        assert!(w[1] <= w[0] || w[0].is_infinite());
    }
}

/// The event loop is deterministic given a fixed seed (same optimum, same
/// trajectory) — over the PJRT surrogate path like its sync counterpart.
#[test]
fn async_seeded_runs_reproduce_exactly() {
    let workload = workloads::by_name("mixed_branin").unwrap();
    let run = || {
        let mut cfg = base(OptimizerKind::Hallucination, 10, 2, 77);
        cfg.mode = ExecutionMode::Async;
        let mut tuner = Tuner::new(workload.space.clone(), cfg);
        let obj = workload.objective.clone();
        tuner.minimize(move |c| obj(c)).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.best_objective, b.best_objective);
    assert_eq!(a.best_series, b.best_series);
    assert_eq!(a.best_params, b.best_params);
}
