//! Trial-level early-stopping tests: pruner property tests (median
//! monotonicity, ASHA rung invariants over arbitrary report streams),
//! decision-determinism tests (byte-identical run-to-run and across the
//! serial / threaded / celery-sim schedulers and every
//! proposal-threads × proposal-shards setting), and the `--pruner none`
//! byte-identity guard that pins the pre-pruning path.

use mango::coordinator::{ExecutionMode, Tuner, TunerConfig, TuningResult};
use mango::optimizer::prune::{
    AsyncSuccessiveHalving, MedianRule, Pruner, PrunerKind, ReportBook,
};
use mango::optimizer::{OptimizerKind, SurrogateBackend};
use mango::persist::{self, AsyncReplay, Replay};
use mango::scheduler::celery::CelerySimConfig;
use mango::scheduler::{SchedulerKind, TrialReporter};
use mango::space::{Config, SearchSpace};
use mango::util::proptest::{check, Gen};
use std::path::PathBuf;
use std::time::Duration;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("mango_pruning_{}_{name}.jsonl", std::process::id()))
}

fn space() -> SearchSpace {
    SearchSpace::builder().uniform("x", 0.0, 10.0).build()
}

/// Staged objective: four intermediate reports ramping up to the final
/// value, honouring a prune decision by returning early.
fn staged(cfg: &Config, reporter: &TrialReporter) -> Option<f64> {
    let base = cfg.get_f64("x")?;
    for step in 0..4u64 {
        let v = base * ((step + 1) as f64) / 4.0;
        if !reporter.report(step, v) {
            return Some(v);
        }
    }
    Some(base)
}

/// The same objective with the report channel ignored — must be what
/// `--pruner none` behaves like, byte for byte.
fn plain(cfg: &Config) -> Option<f64> {
    cfg.get_f64("x")
}

fn async_config(scheduler: SchedulerKind, pruner: PrunerKind) -> TunerConfig {
    TunerConfig {
        optimizer: OptimizerKind::Tpe,
        num_iterations: 12,
        batch_size: 1,
        initial_random: 2,
        backend: SurrogateBackend::Native,
        mode: ExecutionMode::Async,
        scheduler,
        workers: 1,
        async_window: 1,
        seed: 7,
        pruner,
        pruner_warmup: 1,
        asha_reduction: 2.0,
        ..Default::default()
    }
}

/// A fault-free celery sim: full distributed machinery (broker queue,
/// result collector, pre-rolled fates) with every fate `Deliver`.
fn quiet_celery() -> CelerySimConfig {
    CelerySimConfig {
        workers: 1,
        base_latency_ms: 0.1,
        straggler_prob: 0.0,
        straggler_factor: 1.0,
        crash_prob: 0.0,
        result_timeout: Duration::from_secs(30),
    }
}

/// Run the staged objective journaled, then recover the journal so the
/// test sees exactly the decision record a resumed process would.
fn run_staged(cfg: TunerConfig, name: &str) -> (TuningResult, AsyncReplay, String) {
    let path = tmp(name);
    let _ = std::fs::remove_file(&path);
    let mut tuner = Tuner::new(space(), cfg).with_journal(&path);
    let result = tuner.maximize_with_reports(staged).expect("tuning run");
    let text = std::fs::read_to_string(&path).expect("journal readable");
    let rec = persist::recover(&path).expect("journal recovers");
    let Replay::Async(replay) = rec.replay else { panic!("expected an async replay") };
    let _ = std::fs::remove_file(&path);
    (result, replay, text)
}

/// The run's decision-relevant record, bit-exact: every journaled
/// intermediate report with its prune decision, the surrogate history
/// (f64 bit patterns, so censored values compare exactly), and counters.
#[derive(Debug, PartialEq, Eq)]
struct DecisionTrace {
    reports: Vec<(u64, u64, u64, bool)>,
    history_bits: Vec<u64>,
    best_bits: u64,
    pruned: u64,
    evaluations: usize,
}

fn trace(result: &TuningResult, replay: &AsyncReplay) -> DecisionTrace {
    DecisionTrace {
        reports: replay.reports.iter().map(|&(p, s, v, d)| (p, s, v.to_bits(), d)).collect(),
        history_bits: result.history.iter().map(|(_, v)| v.to_bits()).collect(),
        best_bits: result.best_objective.to_bits(),
        pruned: result.pruned,
        evaluations: result.evaluations,
    }
}

/// Random report book: 2-6 trials, each with 1-5 reports at consecutive
/// steps.
fn random_book(g: &mut Gen) -> ReportBook {
    let n_pids = g.usize_range(2, 7);
    let mut b = ReportBook::new();
    for pid in 0..n_pids as u64 {
        for step in 0..g.usize_range(1, 6) as u64 {
            b.push(pid, step, g.f64_range(-5.0, 5.0));
        }
    }
    b
}

/// Rebuild a book with the streams unchanged except (optionally) one
/// trial's latest value replaced.
fn rebuild(book: &ReportBook, patch: Option<(u64, f64)>) -> ReportBook {
    let mut out = ReportBook::new();
    for pid in book.pids().collect::<Vec<_>>() {
        let reps = book.reports(pid);
        for (i, &(s, v)) in reps.iter().enumerate() {
            let v = match patch {
                Some((p, nv)) if p == pid && i == reps.len() - 1 => nv,
                _ => v,
            };
            out.push(pid, s, v);
        }
    }
    out
}

// ---- property tests ----

/// Median-rule monotonicity: lowering a trial's latest value can flip a
/// decision toward pruning but never away from it (ties survive, strict
/// inequality prunes).
#[test]
fn median_rule_lowering_latest_value_never_unprunes() {
    check("median monotonicity", 96, |g| {
        let rule = MedianRule { warmup: g.usize_range(1, 4) };
        let book = random_book(g);
        let pids: Vec<u64> = book.pids().collect();
        let pid = *g.choose(&pids);
        let before = rule.should_prune(pid, &book);
        let &(_, latest) = book.reports(pid).last().expect("every pid reported");
        let lowered = rebuild(&book, Some((pid, latest - g.f64_range(0.1, 10.0))));
        let after = rule.should_prune(pid, &lowered);
        if before && !after {
            return Err(format!("lowering pid {pid}'s latest value un-pruned it"));
        }
        Ok(())
    });
}

/// ASHA rung invariants on arbitrary streams, checked against an
/// independent oracle: below the first milestone nothing prunes, a rung's
/// leader always survives, and the decision equals the documented
/// rank-vs-keep rule at the highest reached rung.
#[test]
fn asha_rung_invariants_on_arbitrary_streams() {
    check("asha rung invariants", 96, |g| {
        let r0 = g.usize_range(1, 4) as u64;
        let eta = *g.choose(&[2.0, 3.0, 4.0]);
        let rule = AsyncSuccessiveHalving { r0, eta };
        let book = random_book(g);
        for pid in book.pids().collect::<Vec<_>>() {
            let &(step, _) = book.reports(pid).last().expect("every pid reported");
            // Oracle rung: highest k with r0 * eta^k <= step.
            if (step as f64) < r0 as f64 {
                if rule.should_prune(pid, &book) {
                    return Err(format!("pid {pid} pruned below the first milestone"));
                }
                continue;
            }
            let mut k = 0i32;
            while (r0 as f64) * eta.powi(k + 1) <= step as f64 {
                k += 1;
            }
            let milestone = (r0 as f64) * eta.powi(k);
            let rung_value = |p: u64| {
                book.reports(p).iter().find(|(s, _)| (*s as f64) >= milestone).map(|&(_, v)| v)
            };
            let Some(mine) = rung_value(pid) else { continue };
            let rung: Vec<f64> = book.pids().filter_map(rung_value).collect();
            let keep = (((rung.len() as f64) / eta).floor() as usize).max(1);
            let rank = rung.iter().filter(|v| **v > mine).count();
            let decision = rule.should_prune(pid, &book);
            let best = rung.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            if mine == best && decision {
                return Err(format!("pid {pid} leads rung {k} yet was pruned"));
            }
            if decision != (rank >= keep) {
                return Err(format!(
                    "pid {pid} at rung {k}: decision {decision}, oracle rank {rank} \
                     vs keep {keep} of {}",
                    rung.len()
                ));
            }
        }
        Ok(())
    });
}

/// Decisions are a pure function of the streams, not of the order trials
/// were inserted into the book.
#[test]
fn decisions_are_insertion_order_invariant() {
    check("pruner permutation invariance", 48, |g| {
        let book = random_book(g);
        let mut reversed = ReportBook::new();
        for pid in book.pids().collect::<Vec<_>>().into_iter().rev() {
            for &(s, v) in book.reports(pid) {
                reversed.push(pid, s, v);
            }
        }
        let median = MedianRule { warmup: 1 };
        let asha = AsyncSuccessiveHalving { r0: 1, eta: 2.0 };
        for pid in book.pids().collect::<Vec<_>>() {
            if median.should_prune(pid, &book) != median.should_prune(pid, &reversed) {
                return Err(format!("median decision for pid {pid} depends on insertion order"));
            }
            if asha.should_prune(pid, &book) != asha.should_prune(pid, &reversed) {
                return Err(format!("asha decision for pid {pid} depends on insertion order"));
            }
        }
        Ok(())
    });
}

// ---- determinism tests ----

/// The same pruned run twice: every report, decision, censored history
/// value, and counter is bit-identical.
#[test]
fn pruned_run_decisions_are_identical_run_to_run() {
    for pruner in [PrunerKind::Median, PrunerKind::Asha] {
        let cfg = || async_config(SchedulerKind::Serial, pruner);
        let (r1, a1, _) = run_staged(cfg(), &format!("rerun_a_{pruner:?}"));
        let (r2, a2, _) = run_staged(cfg(), &format!("rerun_b_{pruner:?}"));
        assert!(r1.pruned >= 1, "{pruner:?}: the staged workload must actually prune");
        assert!(r1.reports >= 1);
        assert_eq!(trace(&r1, &a1), trace(&r2, &a2), "{pruner:?} decisions drifted run-to-run");
    }
}

/// Serial, threaded, and celery-sim (fault-free) schedulers deliver the
/// same report streams, so the pruner must reach byte-identical decisions
/// and censored history on all three.
#[test]
fn pruned_run_decisions_are_identical_across_schedulers() {
    for pruner in [PrunerKind::Median, PrunerKind::Asha] {
        let (r_serial, a_serial, _) =
            run_staged(async_config(SchedulerKind::Serial, pruner), &format!("xs_serial_{pruner:?}"));
        assert!(r_serial.pruned >= 1, "{pruner:?}: the staged workload must actually prune");
        let reference = trace(&r_serial, &a_serial);

        let (r_thr, a_thr, _) = run_staged(
            async_config(SchedulerKind::Threaded, pruner),
            &format!("xs_threaded_{pruner:?}"),
        );
        assert_eq!(trace(&r_thr, &a_thr), reference, "{pruner:?}: threaded drifted from serial");

        let mut celery_cfg = async_config(SchedulerKind::Celery, pruner);
        celery_cfg.celery = Some(quiet_celery());
        let (r_cel, a_cel, _) = run_staged(celery_cfg, &format!("xs_celery_{pruner:?}"));
        assert_eq!(trace(&r_cel, &a_cel), reference, "{pruner:?}: celery-sim drifted from serial");
    }
}

/// Proposal-scoring parallelism knobs are wall-clock knobs, never numerics
/// knobs: pruning decisions are identical at every proposal-threads ×
/// proposal-shards setting.
#[test]
fn pruned_run_decisions_are_invariant_to_proposal_threads_and_shards() {
    let gp_config = |threads: usize, shards: usize| {
        let mut cfg = async_config(SchedulerKind::Serial, PrunerKind::Median);
        cfg.optimizer = OptimizerKind::Hallucination;
        cfg.num_iterations = 8;
        cfg.mc_samples = 128;
        cfg.proposal_threads = threads;
        cfg.proposal_shards = shards;
        cfg
    };
    let (r0, a0, _) = run_staged(gp_config(1, 0), "knobs_t1_s0");
    let reference = trace(&r0, &a0);
    assert!(r0.reports >= 1);
    for (threads, shards) in [(2, 0), (4, 0), (1, 2), (2, 3)] {
        let (r, a, _) = run_staged(gp_config(threads, shards), &format!("knobs_t{threads}_s{shards}"));
        assert_eq!(
            trace(&r, &a),
            reference,
            "decisions drifted at proposal_threads={threads} proposal_shards={shards}"
        );
    }
}

// ---- `--pruner none` byte-identity guard ----

/// With the pruner off, a reporting objective takes exactly today's path:
/// the journal carries no report events, the counters stay zero, and the
/// result is bit-identical to the same run driven through plain
/// `maximize`.
#[test]
fn pruner_none_is_byte_identical_to_the_pre_pruning_path() {
    let (with_reports, replay, journal_text) =
        run_staged(async_config(SchedulerKind::Serial, PrunerKind::None), "none_reporting");
    assert_eq!(with_reports.pruned, 0);
    assert_eq!(with_reports.reports, 0);
    assert!(replay.reports.is_empty(), "pruner none must journal no reports");
    assert_eq!(replay.pruned, 0);
    assert!(
        !journal_text.contains("\"async_report\""),
        "pruner none must not emit async_report events"
    );

    let path = tmp("none_plain");
    let _ = std::fs::remove_file(&path);
    let mut tuner = Tuner::new(space(), async_config(SchedulerKind::Serial, PrunerKind::None))
        .with_journal(&path);
    let baseline = tuner.maximize(plain).expect("baseline run");
    let _ = std::fs::remove_file(&path);

    assert_eq!(
        with_reports.best_objective.to_bits(),
        baseline.best_objective.to_bits(),
        "best objective drifted"
    );
    assert_eq!(with_reports.best_params, baseline.best_params);
    assert_eq!(with_reports.evaluations, baseline.evaluations);
    let bits = |r: &TuningResult| -> Vec<u64> { r.history.iter().map(|(_, v)| v.to_bits()).collect() };
    assert_eq!(bits(&with_reports), bits(&baseline), "history drifted");
    assert_eq!(with_reports.best_series.len(), baseline.best_series.len());
}

/// Sync mode has no report channel, so configuring a pruner there must be
/// a loud configuration error, not a silent no-op.
#[test]
fn sync_mode_refuses_pruners() {
    let mut cfg = async_config(SchedulerKind::Serial, PrunerKind::Median);
    cfg.mode = ExecutionMode::Sync;
    let err = Tuner::new(space(), cfg).maximize_with_reports(staged).unwrap_err();
    assert!(
        err.to_string().contains("requires async mode"),
        "unexpected error: {err:#}"
    );
}
