//! Contract tests for `pallas-lint` itself: every rule R1–R6 is
//! demonstrated by a fixture that fails on a seeded violation and passes
//! once fixed or pragma'd; pragma suppression, baseline round-trip, and —
//! the point of the exercise — the real tree is clean under the committed
//! baseline, whose size is pinned so it can only shrink.

use mango::lint::{self, Baseline, Finding, LintReport, RuleId};
use std::fs;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

/// A unique scratch dir per call (std-only; no tempfile crate offline).
struct Scratch {
    root: PathBuf,
}

impl Scratch {
    fn new(files: &[(&str, &str)]) -> Self {
        static N: AtomicUsize = AtomicUsize::new(0);
        let root = std::env::temp_dir().join(format!(
            "pallas_lint_fixture_{}_{}",
            std::process::id(),
            N.fetch_add(1, Ordering::SeqCst)
        ));
        for (rel, contents) in files {
            let path = root.join(rel);
            fs::create_dir_all(path.parent().expect("fixture paths have parents"))
                .expect("mkdir fixture");
            fs::write(&path, contents).expect("write fixture");
        }
        Self { root }
    }

    fn lint(&self, baseline: Option<&Baseline>) -> LintReport {
        lint::lint_tree(&self.root, baseline).expect("lint fixture tree")
    }
}

impl Drop for Scratch {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.root);
    }
}

fn lint_one(rel: &str, source: &str) -> LintReport {
    Scratch::new(&[(rel, source)]).lint(None)
}

fn assert_single(report: &LintReport, rule: RuleId, line: usize) -> Finding {
    assert_eq!(
        report.findings.len(),
        1,
        "expected exactly one {rule:?} finding, got {:#?}",
        report.findings
    );
    let f = report.findings[0].clone();
    assert_eq!(f.rule, rule, "wrong rule: {f:#?}");
    assert_eq!(f.line, line, "wrong line: {f:#?}");
    f
}

// ---- R1: wall-clock purity -------------------------------------------

const R1_BAD: &str = "use std::time::Instant;\n\
                      pub fn stamp() -> Instant {\n    Instant::now()\n}\n";

#[test]
fn r1_clock_read_in_pure_module_is_flagged() {
    let report = lint_one("gp/bad_clock.rs", R1_BAD);
    assert_single(&report, RuleId::R1, 3);
}

#[test]
fn r1_same_code_outside_pure_modules_is_fine() {
    let report = lint_one("scheduler/telemetry.rs", R1_BAD);
    assert!(report.findings.is_empty(), "{:#?}", report.findings);
}

#[test]
fn r1_pragma_with_reason_suppresses() {
    let src = "pub fn stamp() -> std::time::Instant {\n    \
               std::time::Instant::now() // pallas-lint: allow(R1, \"telemetry only\")\n}\n";
    let report = lint_one("gp/bad_clock.rs", src);
    assert!(report.findings.is_empty(), "{:#?}", report.findings);
    assert_eq!(report.suppressed, 1);
}

#[test]
fn r1_system_time_is_flagged_too() {
    let src = "pub fn t() -> u64 {\n    let _ = std::time::SystemTime::now();\n    0\n}\n";
    let report = lint_one("persist/bad.rs", src);
    assert_single(&report, RuleId::R1, 2);
}

// ---- R2: NaN-safe ordering -------------------------------------------

#[test]
fn r2_partial_cmp_unwrap_is_flagged_everywhere() {
    let src = "pub fn sortit(v: &mut [f64]) {\n    \
               v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
    // util/ is outside every module scope — R2 applies globally.
    let report = lint_one("util/sortit.rs", src);
    assert_single(&report, RuleId::R2, 2);
}

#[test]
fn r2_catches_unwrap_on_the_next_line() {
    let src = "pub fn sortit(v: &mut [f64]) {\n    v.sort_by(|a, b| {\n        \
               a.partial_cmp(b)\n            .expect(\"no NaN\")\n    });\n}\n";
    let report = lint_one("ml/sortit.rs", src);
    assert_single(&report, RuleId::R2, 3);
}

#[test]
fn r2_total_cmp_fix_passes() {
    let src = "pub fn sortit(v: &mut [f64]) {\n    v.sort_by(|a, b| a.total_cmp(b));\n}\n";
    let report = lint_one("util/sortit.rs", src);
    assert!(report.findings.is_empty(), "{:#?}", report.findings);
}

#[test]
fn r2_partial_cmp_with_unwrap_or_fallback_passes() {
    let src = "pub fn sortit(v: &mut [f64]) {\n    v.sort_by(|a, b| \
               a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Less));\n}\n";
    let report = lint_one("util/sortit.rs", src);
    assert!(report.findings.is_empty(), "{:#?}", report.findings);
}

// ---- R3: deterministic iteration -------------------------------------

#[test]
fn r3_hash_container_in_decision_path_is_flagged() {
    let src = "use std::collections::HashMap;\npub fn m() -> HashMap<u32, u32> {\n    \
               HashMap::new()\n}\n";
    let report = lint_one("optimizer/bad_map.rs", src);
    assert_eq!(report.findings.len(), 3, "{:#?}", report.findings);
    assert!(report.findings.iter().all(|f| f.rule == RuleId::R3));
    assert_eq!(report.findings[0].line, 1);
}

#[test]
fn r3_btree_fix_passes() {
    let src = "use std::collections::BTreeMap;\npub fn m() -> BTreeMap<u32, u32> {\n    \
               BTreeMap::new()\n}\n";
    let report = lint_one("optimizer/good_map.rs", src);
    assert!(report.findings.is_empty(), "{:#?}", report.findings);
}

#[test]
fn r3_pragma_proving_lookup_only_suppresses() {
    let src = "// pallas-lint: allow(R3, \"lookup-only cache, never iterated\")\n\
               use std::collections::HashSet;\n";
    let report = lint_one("space/cache.rs", src);
    assert!(report.findings.is_empty(), "{:#?}", report.findings);
    assert_eq!(report.suppressed, 1);
}

// ---- R4: seeded randomness only --------------------------------------

#[test]
fn r4_ambient_entropy_is_flagged() {
    let src = "pub fn draw() -> u64 {\n    rand::thread_rng().gen()\n}\n";
    let report = lint_one("cli/anywhere.rs", src);
    assert_single(&report, RuleId::R4, 2);
}

#[test]
fn r4_util_rng_is_exempt() {
    let src = "pub fn seed_from_entropy() -> u64 {\n    \
               // the one place entropy may enter (it never does today):\n    \
               thread_rng_shim()\n}\nfn thread_rng_shim() -> u64 { 4 }\n";
    // `thread_rng` appears only as part of the longer identifier
    // `thread_rng_shim`, which must NOT match (word-boundary check) …
    let report = lint_one("gp/word_boundary.rs", src);
    assert!(report.findings.is_empty(), "{:#?}", report.findings);
    // … while the real token inside util/rng.rs is exempt by scope.
    let report = lint_one("util/rng.rs", "pub fn x() { let _ = thread_rng(); }\n");
    assert!(report.findings.is_empty(), "{:#?}", report.findings);
}

// ---- R5: no-panic recovery paths -------------------------------------

#[test]
fn r5_unwrap_on_recovery_path_is_flagged() {
    let src = "pub fn recover(s: &str) -> u32 {\n    s.parse::<u32>().unwrap()\n}\n";
    let report = lint_one("persist/recover.rs", src);
    assert_single(&report, RuleId::R5, 2);
}

#[test]
fn r5_panic_macro_in_worker_file_is_flagged() {
    let src = "pub fn w(x: u32) {\n    if x > 3 {\n        panic!(\"boom\");\n    }\n}\n";
    let report = lint_one("scheduler/pool.rs", src);
    assert_single(&report, RuleId::R5, 3);
}

#[test]
fn r5_skips_cfg_test_modules() {
    let src = "pub fn ok() {}\n\n#[cfg(test)]\nmod tests {\n    #[test]\n    \
               fn t() {\n        Some(1).unwrap();\n        panic!(\"fine in tests\");\n    }\n}\n";
    let report = lint_one("persist/recover.rs", src);
    assert!(report.findings.is_empty(), "{:#?}", report.findings);
}

#[test]
fn r5_result_fix_passes() {
    let src = "pub fn recover(s: &str) -> Result<u32, std::num::ParseIntError> {\n    \
               s.parse::<u32>()\n}\n";
    let report = lint_one("persist/recover.rs", src);
    assert!(report.findings.is_empty(), "{:#?}", report.findings);
}

// ---- R6: atomics/locking hygiene -------------------------------------

#[test]
fn r6_bare_lock_unwrap_in_scheduler_is_flagged() {
    let src = "use std::sync::Mutex;\npub fn g(m: &Mutex<u32>) -> u32 {\n    \
               *m.lock().unwrap()\n}\n";
    let report = lint_one("scheduler/broker.rs", src);
    assert_single(&report, RuleId::R6, 3);
}

#[test]
fn r6_relaxed_ordering_is_flagged() {
    let src = "use std::sync::atomic::{AtomicU64, Ordering};\n\
               pub fn bump(c: &AtomicU64) {\n    c.fetch_add(1, Ordering::Relaxed);\n}\n";
    let report = lint_one("scheduler/stats.rs", src);
    assert_single(&report, RuleId::R6, 3);
}

#[test]
fn r6_justification_pragma_suppresses() {
    let src = "use std::sync::Mutex;\npub fn g(m: &Mutex<u32>) -> u32 {\n    \
               *m.lock().unwrap() // pallas-lint: allow(R6, \"poison propagation is the contract\")\n}\n";
    let report = lint_one("scheduler/broker.rs", src);
    assert!(report.findings.is_empty(), "{:#?}", report.findings);
    assert_eq!(report.suppressed, 1);
}

#[test]
fn r6_same_lock_outside_scheduler_is_fine() {
    let src = "use std::sync::Mutex;\npub fn g(m: &Mutex<u32>) -> u32 {\n    \
               *m.lock().unwrap()\n}\n";
    let report = lint_one("util/anywhere.rs", src);
    assert!(report.findings.is_empty(), "{:#?}", report.findings);
}

// ---- pragmas ----------------------------------------------------------

#[test]
fn pragma_without_reason_is_a_p0_finding() {
    let src = "use std::collections::HashMap; // pallas-lint: allow(R3)\n";
    let report = lint_one("gp/x.rs", src);
    // The malformed pragma does not suppress, so both P0 and R3 surface.
    assert_eq!(report.findings.len(), 2, "{:#?}", report.findings);
    assert!(report.findings.iter().any(|f| f.rule == RuleId::P0));
    assert!(report.findings.iter().any(|f| f.rule == RuleId::R3));
}

#[test]
fn pragma_for_wrong_rule_does_not_suppress() {
    let src = "use std::collections::HashMap; // pallas-lint: allow(R1, \"wrong rule\")\n";
    let report = lint_one("gp/x.rs", src);
    assert_single(&report, RuleId::R3, 1);
}

// ---- baseline ---------------------------------------------------------

#[test]
fn baseline_round_trip_grandfathers_then_only_shrinks() {
    let bad_gp = "pub fn t() -> std::time::Instant {\n    std::time::Instant::now()\n}\n";
    let bad_opt = "use std::collections::HashMap;\n";
    let scratch = Scratch::new(&[("gp/clock.rs", bad_gp), ("optimizer/map.rs", bad_opt)]);

    // 1. Ungated run sees both findings.
    let before = scratch.lint(None);
    assert_eq!(before.findings.len(), 2, "{:#?}", before.findings);

    // 2. Write the baseline, round-trip it through disk.
    let baseline_path = scratch.root.join("lint-baseline.json");
    Baseline::from_findings(&before.findings, "grandfathered for the round-trip test")
        .save(&baseline_path)
        .expect("save baseline");
    let baseline = Baseline::load(&baseline_path).expect("reload baseline");
    assert_eq!(baseline.entries.len(), 2);

    // 3. Re-run under the baseline: zero new findings, nothing stale.
    let after = scratch.lint(Some(&baseline));
    assert!(after.findings.is_empty(), "{:#?}", after.findings);
    assert_eq!(after.baselined, 2);
    assert!(after.stale_baseline.is_empty());

    // 4. Fix one violation: its entry goes stale (the baseline only
    //    shrinks), and still zero new findings.
    fs::write(scratch.root.join("gp/clock.rs"), "pub fn t() {}\n").expect("rewrite fixture");
    let shrunk = scratch.lint(Some(&baseline));
    assert!(shrunk.findings.is_empty(), "{:#?}", shrunk.findings);
    assert_eq!(shrunk.baselined, 1);
    assert_eq!(shrunk.stale_baseline.len(), 1);
    assert_eq!(shrunk.stale_baseline[0].file, "gp/clock.rs");
}

#[test]
fn baseline_does_not_absolve_new_findings_on_other_lines() {
    let scratch = Scratch::new(&[("linalg/x.rs", "use std::collections::HashMap;\n")]);
    let before = scratch.lint(None);
    let baseline = Baseline::from_findings(&before.findings, "one entry only");
    // A second, different violation appears.
    fs::write(
        scratch.root.join("linalg/x.rs"),
        "use std::collections::HashMap;\nuse std::collections::HashSet;\n",
    )
    .expect("rewrite fixture");
    let after = scratch.lint(Some(&baseline));
    assert_eq!(after.baselined, 1);
    assert_eq!(after.findings.len(), 1, "{:#?}", after.findings);
    assert_eq!(after.findings[0].line, 2);
}

// ---- the real tree ----------------------------------------------------

/// The acceptance gate, as a test: `rust/src` is clean under the committed
/// baseline. Mirrors CI's `cargo run --bin pallas-lint -- --deny`.
#[test]
fn real_tree_is_clean_under_committed_baseline() {
    let crate_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let baseline =
        Baseline::load(&crate_dir.join("lint-baseline.json")).expect("committed baseline");
    let report =
        lint::lint_tree(&crate_dir.join("src"), Some(&baseline)).expect("lint rust/src");
    assert!(
        report.findings.is_empty(),
        "new contract violations (fix, pragma with a reason, or — last resort — \
         regenerate the baseline): {:#?}",
        report.findings
    );
    assert!(
        report.stale_baseline.is_empty(),
        "baseline entries no longer match — shrink lint-baseline.json: {:#?}",
        report.stale_baseline
    );
}

/// The committed baseline is EMPTY: the last grandfathered findings (the
/// feature-gated PJRT executable caches) were fixed by migrating them to
/// `BTreeMap`. It must stay empty — new findings get fixed or pragma'd
/// with a reason, never grandfathered.
#[test]
fn committed_baseline_only_shrinks() {
    let crate_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let baseline =
        Baseline::load(&crate_dir.join("lint-baseline.json")).expect("committed baseline");
    assert!(
        baseline.entries.is_empty(),
        "lint-baseline.json grew to {} entries — new findings must be fixed or \
         pragma'd, not grandfathered: {:#?}",
        baseline.entries.len(),
        baseline.entries
    );
}

/// Sanity: the audited pragmas in the live tree actually suppress
/// something (a renamed rule or moved pragma would silently rot).
#[test]
fn live_tree_pragmas_are_load_bearing() {
    let crate_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let report = lint::lint_tree(&crate_dir.join("src"), None).expect("lint rust/src");
    // The PR 7 audit sweep: 2x R1 (gp shard telemetry), 1x R3 (update.rs
    // membership-only set), 1x R5 (condvar poison), 5x R6 (broker poison
    // policy). New pragmas only ever raise this floor.
    assert!(
        report.suppressed >= 9,
        "expected the audited pragmas to suppress >= 9 findings, got {}",
        report.suppressed
    );
}
