//! Cross-module property tests: invariants that must hold across the
//! optimizer/scheduler/space boundaries for *any* search space — in both
//! the batch-synchronous and async submit/poll contracts.

use mango::coordinator::{ExecutionMode, Tuner, TunerConfig};
use mango::optimizer::{self, BatchOptimizer, GpOptions, History, OptimizerKind, SurrogateBackend};
use mango::scheduler::{self, CompletionStatus, SchedulerKind};
use mango::space::{Config, Domain, ParamValue, SearchSpace};
use mango::util::proptest::{check, Gen};
use mango::util::rng::Pcg64;

/// Build a random search space with mixed domain types.
fn random_space(g: &mut Gen) -> SearchSpace {
    let n_params = g.usize_range(1, 5);
    let mut b = SearchSpace::builder();
    for i in 0..n_params {
        let name = format!("p{i}");
        match g.usize_range(0, 4) {
            0 => {
                let lo = g.f64_range(-10.0, 10.0);
                b = b.uniform(&name, lo, lo + g.f64_range(0.1, 20.0));
            }
            1 => {
                let lo = g.f64_range(1e-4, 1.0);
                b = b.loguniform(&name, lo, lo * g.f64_range(10.0, 1e4));
            }
            2 => {
                let lo = g.f64_range(-50.0, 50.0) as i64;
                b = b.int(&name, lo, lo + g.usize_range(1, 30) as i64);
            }
            _ => {
                b = b.choice(&name, &["a", "b", "c", "d"][..g.usize_range(2, 5)]);
            }
        }
    }
    b.build()
}

/// Does `value` lie inside `domain`?
fn in_domain(domain: &Domain, v: &ParamValue) -> bool {
    match (domain, v) {
        // closed intervals: scipy.stats.uniform's support is [loc, loc+scale]
        (Domain::Uniform { lo, hi }, ParamValue::F64(x)) => (lo..=hi).contains(&x),
        (Domain::LogUniform { lo, hi }, ParamValue::F64(x)) => (lo..=hi).contains(&x),
        (Domain::Range { lo, hi }, ParamValue::Int(x)) => (lo..=hi).contains(&x),
        (Domain::Choice(vals), v) => vals.contains(v),
        _ => false,
    }
}

/// Every optimizer's proposals must be valid members of the space —
/// the paper's "acquisition evaluated at valid configurations only".
#[test]
fn all_optimizers_propose_valid_configs() {
    check("optimizer proposals in-domain", 24, |g| {
        let space = random_space(g);
        let kind = *g.choose(&[
            OptimizerKind::Random,
            OptimizerKind::Tpe,
            OptimizerKind::Hallucination,
            OptimizerKind::Clustering,
        ]);
        // Native backend: these property runs hammer many tiny spaces.
        let opts = GpOptions { mc_samples: 128, ..Default::default() };
        let mut opt = optimizer::build(kind, &space, &opts).map_err(|e| e.to_string())?;
        let mut rng = Pcg64::new(g.rng().next_u64());
        // Seed a synthetic history so the model-based paths engage.
        let mut history = History::new();
        for (i, cfg) in space.sample_n(&mut rng, 25).into_iter().enumerate() {
            history.push(cfg, (i as f64 * 0.7).sin());
        }
        let k = g.usize_range(1, 7);
        let batch = opt.propose(&history, k, &mut rng).map_err(|e| e.to_string())?;
        if batch.len() != k {
            return Err(format!("{kind:?} proposed {} of {k}", batch.len()));
        }
        for cfg in &batch {
            for p in space.params() {
                let v = cfg
                    .get(&p.name)
                    .ok_or_else(|| format!("{kind:?}: missing {}", p.name))?;
                if !in_domain(&p.domain, v) {
                    return Err(format!("{kind:?}: {} = {v} outside {:?}", p.name, p.domain));
                }
            }
        }
        Ok(())
    });
}

/// Scheduler results must be a subset of the submitted batch with aligned
/// (evals, params) — the paper's fault-tolerance contract.
#[test]
fn schedulers_return_aligned_subsets() {
    check("scheduler subset+alignment", 20, |g| {
        let space = random_space(g);
        let mut rng = Pcg64::new(g.rng().next_u64());
        let batch = space.sample_n(&mut rng, g.usize_range(1, 12));
        let kind = *g.choose(&[
            SchedulerKind::Serial,
            SchedulerKind::Threaded,
            SchedulerKind::Celery,
        ]);
        let mut sched = scheduler::build(kind, 4, g.rng().next_u64());
        // Deterministic value function with occasional failures.
        let f = |cfg: &Config| {
            let h = format!("{cfg}").len() as f64;
            if (h as u64) % 7 == 0 {
                None
            } else {
                Some(h * 0.1)
            }
        };
        let result = sched.evaluate(&f, &batch);
        if result.evals.len() != result.params.len() {
            return Err("misaligned".into());
        }
        if result.len() > batch.len() {
            return Err("more results than tasks".into());
        }
        for (cfg, v) in result.params.iter().zip(&result.evals) {
            if !batch.contains(cfg) {
                return Err(format!("result config {cfg} not in batch"));
            }
            match f(cfg) {
                Some(want) if (want - v).abs() < 1e-12 => {}
                other => return Err(format!("value mismatch: {v} vs {other:?}")),
            }
        }
        Ok(())
    });
}

/// Async schedulers must conclude every submission exactly once: ids are
/// assigned in submission order, and the drained completions carry the
/// submitted configs with correct values (or explicit loss events) — the
/// fault-tolerance contract without silent drops.
#[test]
fn async_schedulers_conclude_every_submission() {
    check("async scheduler conclude-once", 20, |g| {
        let space = random_space(g);
        let mut rng = Pcg64::new(g.rng().next_u64());
        let batch = space.sample_n(&mut rng, g.usize_range(1, 12));
        let kind = *g.choose(&[
            SchedulerKind::Serial,
            SchedulerKind::Threaded,
            SchedulerKind::Celery,
        ]);
        // Keep the Celery sim lossy-but-fast: losses are fine (they must
        // still *report*), eternal stragglers are not.
        let celery = scheduler::celery::CelerySimConfig {
            workers: 4,
            base_latency_ms: 0.5,
            straggler_prob: 0.1,
            straggler_factor: 3.0,
            crash_prob: 0.2,
            result_timeout: std::time::Duration::from_secs(2),
        };
        let f = |cfg: &Config| {
            let h = format!("{cfg}").len() as f64;
            if (h as u64) % 7 == 0 {
                None
            } else {
                Some(h * 0.1)
            }
        };
        let seed = g.rng().next_u64();
        let task_f = |_: scheduler::TaskId, cfg: &Config| f(cfg);
        std::thread::scope(|scope| {
            let mut sched = scheduler::build_async(kind, 4, seed, Some(celery), scope, &task_f);
            let ids = sched.submit(&batch);
            if ids != (0..batch.len() as u64).collect::<Vec<_>>() {
                return Err(format!("ids not sequential: {ids:?}"));
            }
            let comps = sched.drain(std::time::Duration::from_secs(30));
            if comps.len() != batch.len() {
                return Err(format!(
                    "{} submissions, {} completions (silent drop?)",
                    batch.len(),
                    comps.len()
                ));
            }
            let mut seen = std::collections::HashSet::new();
            for c in &comps {
                if !seen.insert(c.id) {
                    return Err(format!("task {} concluded twice", c.id));
                }
                if c.config != batch[c.id as usize] {
                    return Err(format!("task {} returned a foreign config", c.id));
                }
                match c.status {
                    CompletionStatus::Done(v) => match f(&c.config) {
                        Some(want) if (want - v).abs() < 1e-12 => {}
                        other => return Err(format!("value mismatch: {v} vs {other:?}")),
                    },
                    CompletionStatus::Failed => {
                        if f(&c.config).is_some() {
                            return Err("spurious failure".into());
                        }
                    }
                    CompletionStatus::Lost(_) => {
                        if kind != SchedulerKind::Celery {
                            return Err(format!("{kind:?} must never lose work"));
                        }
                    }
                }
            }
            if sched.in_flight() != 0 {
                return Err("drain left work in flight".into());
            }
            Ok(())
        })
    });
}

/// The async event loop must uphold the coordinator invariants on *any*
/// space: full budget on a reliable scheduler, one best-series point per
/// concluded proposal (monotone in the user sense), and every evaluated
/// config a valid member of the space.
#[test]
fn async_event_loop_invariants_hold_on_random_spaces() {
    check("async event loop invariants", 12, |g| {
        let space = random_space(g);
        let iters = g.usize_range(2, 6);
        let batch = g.usize_range(1, 4);
        let budget = iters * batch;
        let kind = *g.choose(&[OptimizerKind::Random, OptimizerKind::Tpe]);
        let mut t = Tuner::new(
            space.clone(),
            TunerConfig {
                optimizer: kind,
                num_iterations: iters,
                batch_size: batch,
                backend: SurrogateBackend::Native,
                mode: ExecutionMode::Async,
                scheduler: *g.choose(&[SchedulerKind::Serial, SchedulerKind::Threaded]),
                workers: 3,
                seed: g.rng().next_u64(),
                ..Default::default()
            },
        );
        // Deterministic objective over the encoded config text.
        let r = t
            .maximize(|cfg: &Config| Some((format!("{cfg}").len() as f64 * 0.37).sin()))
            .map_err(|e| e.to_string())?;
        if r.evaluations != budget {
            return Err(format!("reliable run: {} of {budget} evals", r.evaluations));
        }
        if r.best_series.len() != budget {
            return Err(format!("series {} != budget {budget}", r.best_series.len()));
        }
        for w in r.best_series.windows(2) {
            if w[1] < w[0] {
                return Err("maximize best-series decreased".into());
            }
        }
        for (cfg, _) in &r.history {
            for p in space.params() {
                let v = cfg
                    .get(&p.name)
                    .ok_or_else(|| format!("missing {}", p.name))?;
                if !in_domain(&p.domain, v) {
                    return Err(format!("{} = {v} outside {:?}", p.name, p.domain));
                }
            }
        }
        Ok(())
    });
}

/// History truncation keeps the most recent window (surrogate cap).
#[test]
fn history_truncation_keeps_recent() {
    check("history window", 32, |g| {
        let n = g.usize_range(1, 200);
        let cap = g.usize_range(1, 64);
        let mut h = History::new();
        for i in 0..n {
            h.push(
                Config::new(vec![("i".into(), ParamValue::Int(i as i64))]),
                i as f64,
            );
        }
        h.truncate_to_recent(cap);
        let kept = h.len();
        if kept != n.min(cap) {
            return Err(format!("kept {kept}, want {}", n.min(cap)));
        }
        if let Some(first) = h.configs().first() {
            let want = (n - kept) as i64;
            if first.get_i64("i") != Some(want) {
                return Err(format!("oldest kept is {:?}, want {want}", first.get_i64("i")));
            }
        }
        Ok(())
    });
}
