//! Cross-module property tests: invariants that must hold across the
//! optimizer/scheduler/space boundaries for *any* search space.

use mango::optimizer::{self, BatchOptimizer, GpOptions, History, OptimizerKind};
use mango::scheduler::{self, SchedulerKind};
use mango::space::{Config, Domain, ParamValue, SearchSpace};
use mango::util::proptest::{check, Gen};
use mango::util::rng::Pcg64;

/// Build a random search space with mixed domain types.
fn random_space(g: &mut Gen) -> SearchSpace {
    let n_params = g.usize_range(1, 5);
    let mut b = SearchSpace::builder();
    for i in 0..n_params {
        let name = format!("p{i}");
        match g.usize_range(0, 4) {
            0 => {
                let lo = g.f64_range(-10.0, 10.0);
                b = b.uniform(&name, lo, lo + g.f64_range(0.1, 20.0));
            }
            1 => {
                let lo = g.f64_range(1e-4, 1.0);
                b = b.loguniform(&name, lo, lo * g.f64_range(10.0, 1e4));
            }
            2 => {
                let lo = g.f64_range(-50.0, 50.0) as i64;
                b = b.int(&name, lo, lo + g.usize_range(1, 30) as i64);
            }
            _ => {
                b = b.choice(&name, &["a", "b", "c", "d"][..g.usize_range(2, 5)]);
            }
        }
    }
    b.build()
}

/// Does `value` lie inside `domain`?
fn in_domain(domain: &Domain, v: &ParamValue) -> bool {
    match (domain, v) {
        // closed intervals: scipy.stats.uniform's support is [loc, loc+scale]
        (Domain::Uniform { lo, hi }, ParamValue::F64(x)) => (lo..=hi).contains(&x),
        (Domain::LogUniform { lo, hi }, ParamValue::F64(x)) => (lo..=hi).contains(&x),
        (Domain::Range { lo, hi }, ParamValue::Int(x)) => (lo..=hi).contains(&x),
        (Domain::Choice(vals), v) => vals.contains(v),
        _ => false,
    }
}

/// Every optimizer's proposals must be valid members of the space —
/// the paper's "acquisition evaluated at valid configurations only".
#[test]
fn all_optimizers_propose_valid_configs() {
    check("optimizer proposals in-domain", 24, |g| {
        let space = random_space(g);
        let kind = *g.choose(&[
            OptimizerKind::Random,
            OptimizerKind::Tpe,
            OptimizerKind::Hallucination,
            OptimizerKind::Clustering,
        ]);
        // Native backend: these property runs hammer many tiny spaces.
        let opts = GpOptions { mc_samples: 128, ..Default::default() };
        let mut opt = optimizer::build(kind, &space, &opts).map_err(|e| e.to_string())?;
        let mut rng = Pcg64::new(g.rng().next_u64());
        // Seed a synthetic history so the model-based paths engage.
        let mut history = History::new();
        for (i, cfg) in space.sample_n(&mut rng, 25).into_iter().enumerate() {
            history.push(cfg, (i as f64 * 0.7).sin());
        }
        let k = g.usize_range(1, 7);
        let batch = opt.propose(&history, k, &mut rng).map_err(|e| e.to_string())?;
        if batch.len() != k {
            return Err(format!("{kind:?} proposed {} of {k}", batch.len()));
        }
        for cfg in &batch {
            for p in space.params() {
                let v = cfg
                    .get(&p.name)
                    .ok_or_else(|| format!("{kind:?}: missing {}", p.name))?;
                if !in_domain(&p.domain, v) {
                    return Err(format!("{kind:?}: {} = {v} outside {:?}", p.name, p.domain));
                }
            }
        }
        Ok(())
    });
}

/// Scheduler results must be a subset of the submitted batch with aligned
/// (evals, params) — the paper's fault-tolerance contract.
#[test]
fn schedulers_return_aligned_subsets() {
    check("scheduler subset+alignment", 20, |g| {
        let space = random_space(g);
        let mut rng = Pcg64::new(g.rng().next_u64());
        let batch = space.sample_n(&mut rng, g.usize_range(1, 12));
        let kind = *g.choose(&[
            SchedulerKind::Serial,
            SchedulerKind::Threaded,
            SchedulerKind::Celery,
        ]);
        let mut sched = scheduler::build(kind, 4, g.rng().next_u64());
        // Deterministic value function with occasional failures.
        let f = |cfg: &Config| {
            let h = format!("{cfg}").len() as f64;
            if (h as u64) % 7 == 0 {
                None
            } else {
                Some(h * 0.1)
            }
        };
        let result = sched.evaluate(&f, &batch);
        if result.evals.len() != result.params.len() {
            return Err("misaligned".into());
        }
        if result.len() > batch.len() {
            return Err("more results than tasks".into());
        }
        for (cfg, v) in result.params.iter().zip(&result.evals) {
            if !batch.contains(cfg) {
                return Err(format!("result config {cfg} not in batch"));
            }
            match f(cfg) {
                Some(want) if (want - v).abs() < 1e-12 => {}
                other => return Err(format!("value mismatch: {v} vs {other:?}")),
            }
        }
        Ok(())
    });
}

/// History truncation keeps the most recent window (surrogate cap).
#[test]
fn history_truncation_keeps_recent() {
    check("history window", 32, |g| {
        let n = g.usize_range(1, 200);
        let cap = g.usize_range(1, 64);
        let mut h = History::new();
        for i in 0..n {
            h.push(
                Config::new(vec![("i".into(), ParamValue::Int(i as i64))]),
                i as f64,
            );
        }
        h.truncate_to_recent(cap);
        let kept = h.len();
        if kept != n.min(cap) {
            return Err(format!("kept {kept}, want {}", n.min(cap)));
        }
        if let Some(first) = h.configs().first() {
            let want = (n - kept) as i64;
            if first.get_i64("i") != Some(want) {
                return Err(format!("oldest kept is {:?}, want {want}", first.get_i64("i")));
            }
        }
        Ok(())
    });
}
